//! Design-space exploration over accelerator configurations (Sec. V-C,
//! Fig. 16).
//!
//! The paper picks its Edge and Server configurations by sweeping PE
//! counts, buffer sizes, and dataflows against stall/energy surfaces.
//! This module makes that sweep a first-class subsystem: a [`DseSpace`]
//! (PE grid × buffer grid × dataflows × optional tiling knobs) expands
//! into concrete [`DseConfig`] points, a work-stealing parallel
//! [`sweep`] evaluates each point on a forked sim engine under a shared
//! [`SparsitySource`] (measured trace or assumed profile), and the
//! results reduce into a [`ParetoFrontier`] over three objectives —
//! throughput (maximize), energy per sequence (minimize), and an area
//! proxy (minimize) — with a scalarized knee-point recommendation.
//!
//! # Determinism
//!
//! The sweep is embarrassingly parallel but **bit-deterministic**: each
//! point's simulation is single-threaded and IEEE-deterministic, every
//! worker writes its `SimResult` into the slot owned by the point's
//! expansion index, and the report serializer walks points in index
//! order — so the emitted JSON is byte-identical whether the sweep ran
//! on 1 worker or 16.  Anything scheduling-dependent (wall time, cache
//! hit counts) is deliberately kept *out* of the report and surfaced on
//! stderr only.  `rust/tests/determinism.rs` pins this contract.

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use anyhow::{Context, Result};

use crate::model::{OpGraph, TransformerConfig};
use crate::sim::config::AcceleratorConfig;
use crate::sim::dataflow::Dataflow;
use crate::sim::engine::{Engine, SimResult, SparsitySource};
use crate::sim::scheduler::Policy;
use crate::sim::tech::AreaBreakdown;
use crate::util::json::Json;

// ---------------------------------------------------------------------------
// Objectives + Pareto dominance
// ---------------------------------------------------------------------------

/// The three objectives a design point is judged on.
///
/// `throughput` is maximized; `energy` and `area` are minimized.  All
/// three are finite for any simulated point (the engine never emits
/// NaN), but [`dominates`] is written to be safe under NaN anyway: a
/// NaN comparison is `false`, so a NaN point neither dominates nor is
/// reported dominated — it just sits off the frontier.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Objectives {
    /// Sequences per second (higher is better).
    pub throughput: f64,
    /// Millijoules per sequence (lower is better).
    pub energy: f64,
    /// Area proxy in mm² (lower is better).
    pub area: f64,
}

/// Strict Pareto dominance: `a` dominates `b` iff `a` is at least as
/// good on every objective and strictly better on at least one.
///
/// This is a strict partial order — irreflexive (no strict improvement
/// over oneself), antisymmetric (mutual weak improvement forbids any
/// strict one), and transitive (≥ composes and strictness propagates).
/// `rust/tests/dse_pareto.rs` checks these laws on random triples.
pub fn dominates(a: &Objectives, b: &Objectives) -> bool {
    let weak = a.throughput >= b.throughput && a.energy <= b.energy && a.area <= b.area;
    let strict = a.throughput > b.throughput || a.energy < b.energy || a.area < b.area;
    weak && strict
}

/// How far past the frontier a point sits: the largest relative
/// improvement any dominating point achieves over it on any single
/// objective.  `0.0` for non-dominated points.
///
/// This is the "documented epsilon" net for the paper's Edge/Server
/// presets: cost-model tweaks may let a neighbour (e.g. the same PE
/// count with a slightly smaller buffer) weakly dominate a preset, but
/// the preset must stay within [`FRONTIER_EPSILON`] of the surface.
pub fn frontier_gap(objs: &[Objectives], idx: usize) -> f64 {
    let p = &objs[idx];
    let mut gap: f64 = 0.0;
    for q in objs {
        if !dominates(q, p) {
            continue;
        }
        let rel = |better: f64, worse: f64| {
            if worse.abs() > 0.0 {
                ((worse - better) / worse).max(0.0)
            } else {
                0.0
            }
        };
        // Throughput is maximized: improvement is (q - p) / q.
        let t = if q.throughput > 0.0 {
            ((q.throughput - p.throughput) / q.throughput).max(0.0)
        } else {
            0.0
        };
        let e = rel(q.energy, p.energy);
        let a = rel(q.area, p.area);
        gap = gap.max(t.max(e).max(a));
    }
    gap
}

/// Maximum relative distance from the frontier tolerated for the
/// paper's preset configurations in their sanity sweeps (see the unit
/// tests below and DESIGN.md "Design-space exploration").  The known
/// worst case is Edge (64 PE, 13 MB) being weakly dominated by the
/// same PE count at 10 MB — identical cycles, ~9 % less buffer area.
pub const FRONTIER_EPSILON: f64 = 0.15;

/// The non-dominated subset of a sweep, plus a knee-point pick.
#[derive(Clone, Debug, PartialEq)]
pub struct ParetoFrontier {
    /// Indices (into the swept point list) of non-dominated points, in
    /// ascending index order.
    pub indices: Vec<usize>,
    /// The scalarized recommendation: the frontier point closest (in
    /// squared normalized objective space) to the ideal point.  `None`
    /// only for an empty sweep.
    pub knee: Option<usize>,
}

impl ParetoFrontier {
    /// O(n²) dominance filter + knee-point scalarization.
    ///
    /// Knee metric: normalize each objective to `[0, 1]` by the min/max
    /// over the *full* sweep (not just the frontier, so the scale is
    /// ordering-independent), then take the squared Euclidean distance
    /// to the ideal corner (max throughput, min energy, min area).
    /// `sqrt` is monotonic so it is skipped.  Ties break to the lowest
    /// point index, which keeps the knee deterministic under duplicate
    /// objective vectors.
    pub fn compute(objs: &[Objectives]) -> ParetoFrontier {
        let indices: Vec<usize> = (0..objs.len())
            .filter(|&i| !objs.iter().any(|q| dominates(q, &objs[i])))
            .collect();

        let mut t = (f64::INFINITY, f64::NEG_INFINITY);
        let mut e = (f64::INFINITY, f64::NEG_INFINITY);
        let mut a = (f64::INFINITY, f64::NEG_INFINITY);
        for o in objs {
            t = (t.0.min(o.throughput), t.1.max(o.throughput));
            e = (e.0.min(o.energy), e.1.max(o.energy));
            a = (a.0.min(o.area), a.1.max(o.area));
        }
        // A degenerate axis (all points equal) contributes 0 distance.
        let norm = |v: f64, (lo, hi): (f64, f64)| {
            if hi > lo {
                (v - lo) / (hi - lo)
            } else {
                0.0
            }
        };

        let mut knee = None;
        let mut best = f64::INFINITY;
        for &i in &indices {
            let o = &objs[i];
            let dt = 1.0 - norm(o.throughput, t);
            let de = norm(o.energy, e);
            let da = norm(o.area, a);
            let d2 = dt * dt + de * de + da * da;
            if d2 < best {
                best = d2;
                knee = Some(i);
            }
        }
        ParetoFrontier { indices, knee }
    }

    pub fn contains(&self, idx: usize) -> bool {
        self.indices.binary_search(&idx).is_ok()
    }
}

// ---------------------------------------------------------------------------
// Design space
// ---------------------------------------------------------------------------

/// The grid of knobs swept by [`sweep`].
///
/// Every combination of `pes × buffers_mb × dataflows × tiles` becomes
/// one [`DseConfig`], derived from `base` (which supplies everything
/// not swept: memory kind, clock, batch, MAC geometry, DynaTran
/// settings).  Buffer capacity is a single net-MB knob split in the
/// paper's 4:8:1 activation:weight:mask ratio, so the Edge preset
/// (4 + 8 + 1 MB) is *exactly* the 13 MB point and Server
/// (32 + 64 + 8 MB) exactly the 104 MB point of their families.
#[derive(Clone, Debug)]
pub struct DseSpace {
    pub base: AcceleratorConfig,
    pub pes: Vec<usize>,
    pub buffers_mb: Vec<usize>,
    pub dataflows: Vec<Dataflow>,
    /// `(tile_i, tile_j)` output-tile shapes; `tile_b`/`tile_k` stay at
    /// the base config's values (the MAC-lane depth fixes `tile_k`).
    pub tiles: Vec<(usize, usize)>,
}

impl DseSpace {
    /// A space around `base` with the base's own dataflow and tiling:
    /// the caller grows `pes`/`buffers_mb`/`dataflows` from here.
    pub fn around(base: AcceleratorConfig) -> DseSpace {
        let pes = vec![base.pes];
        let buffers_mb = vec![DseSpace::net_buffer_mb(&base)];
        let dataflows = vec![base.dataflow];
        let tiles = vec![(base.tile_i, base.tile_j)];
        DseSpace { base, pes, buffers_mb, dataflows, tiles }
    }

    /// Net on-chip buffer capacity of a config, in whole MB (rounded).
    pub fn net_buffer_mb(cfg: &AcceleratorConfig) -> usize {
        let bytes = cfg.act_buffer_bytes + cfg.weight_buffer_bytes + cfg.mask_buffer_bytes;
        (bytes + (1 << 19)) >> 20
    }

    /// Number of points `expand` will produce.
    pub fn len(&self) -> usize {
        self.pes.len() * self.buffers_mb.len() * self.dataflows.len() * self.tiles.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Expand the grids into concrete configs.
    ///
    /// Nesting order is fixed (`pes` outermost, then `buffers_mb`, then
    /// `dataflows`, then `tiles`) and the position in this order *is*
    /// the point index — the determinism contract and the golden pin
    /// both lean on it, so changing it is a breaking change.
    pub fn expand(&self) -> Vec<DseConfig> {
        let mut out = Vec::with_capacity(self.len());
        for &pes in &self.pes {
            for &buf_mb in &self.buffers_mb {
                for &df in &self.dataflows {
                    for &(ti, tj) in &self.tiles {
                        let mut cfg = self.base.clone();
                        cfg.pes = pes;
                        // 4:8:1 act:weight:mask split of the net MB.
                        let unit = (buf_mb << 20) / 13;
                        cfg.act_buffer_bytes = 4 * unit;
                        cfg.weight_buffer_bytes = 8 * unit;
                        cfg.mask_buffer_bytes = unit;
                        cfg.dataflow = df;
                        cfg.tile_i = ti;
                        cfg.tile_j = tj;
                        cfg.name = format!(
                            "{}-p{}-b{}-{}-t{}x{}",
                            self.base.name,
                            pes,
                            buf_mb,
                            df.compact_name(),
                            ti,
                            tj
                        );
                        let index = out.len();
                        out.push(DseConfig {
                            index,
                            pes,
                            buffer_mb: buf_mb,
                            tile_i: ti,
                            tile_j: tj,
                            cfg,
                        });
                    }
                }
            }
        }
        out
    }
}

/// One expanded point of a [`DseSpace`].
#[derive(Clone, Debug)]
pub struct DseConfig {
    /// Position in the expansion order (stable across runs).
    pub index: usize,
    pub pes: usize,
    pub buffer_mb: usize,
    pub tile_i: usize,
    pub tile_j: usize,
    pub cfg: AcceleratorConfig,
}

/// Hardware-shape cache key: exactly the swept fields that determine a
/// `SimResult` once the workload (model, seq, policy, source) and the
/// base config's unswept fields are fixed for the whole sweep.  Grids
/// with repeated entries (or tiling knobs that collapse to the same
/// shape) hit the cache instead of re-simulating.
#[derive(Clone, PartialEq, Eq, Hash)]
struct SimKey {
    pes: usize,
    act: usize,
    weight: usize,
    mask: usize,
    dataflow: Dataflow,
    tile_i: usize,
    tile_j: usize,
}

impl SimKey {
    fn of(c: &DseConfig) -> SimKey {
        SimKey {
            pes: c.cfg.pes,
            act: c.cfg.act_buffer_bytes,
            weight: c.cfg.weight_buffer_bytes,
            mask: c.cfg.mask_buffer_bytes,
            dataflow: c.cfg.dataflow,
            tile_i: c.cfg.tile_i,
            tile_j: c.cfg.tile_j,
        }
    }
}

// ---------------------------------------------------------------------------
// Sweep
// ---------------------------------------------------------------------------

/// Knobs for [`sweep`].
#[derive(Clone, Copy, Debug)]
pub struct SweepOptions {
    /// Worker threads; `0` resolves `ACCELTRAN_THREADS`, then
    /// `available_parallelism()` capped at 8.  Tests force 1 vs 4 here
    /// (not via the env var — parallel test binaries would race on it).
    pub threads: usize,
    /// Emit progress lines on stderr.
    pub progress: bool,
}

impl Default for SweepOptions {
    fn default() -> Self {
        SweepOptions { threads: 0, progress: false }
    }
}

fn resolve_threads(opts: &SweepOptions, points: usize) -> usize {
    let n = if opts.threads > 0 {
        opts.threads
    } else {
        crate::util::cli::env_usize("ACCELTRAN_THREADS", 0)
    };
    let n = if n > 0 {
        n
    } else {
        std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1).min(8)
    };
    n.clamp(1, points.max(1))
}

/// One evaluated design point: identity, objectives, and the full
/// engine result for drill-down.
#[derive(Clone, Debug)]
pub struct DsePoint {
    pub index: usize,
    pub config_name: String,
    pub pes: usize,
    pub buffer_mb: usize,
    pub dataflow: String,
    pub tile_i: usize,
    pub tile_j: usize,
    pub throughput_seq_s: f64,
    pub energy_mj_per_seq: f64,
    pub area_mm2: f64,
    pub result: SimResult,
}

impl DsePoint {
    pub fn objectives(&self) -> Objectives {
        Objectives {
            throughput: self.throughput_seq_s,
            energy: self.energy_mj_per_seq,
            area: self.area_mm2,
        }
    }

    fn to_json(&self, on_frontier: bool) -> Json {
        Json::obj(vec![
            ("index", Json::num(self.index as f64)),
            ("config", Json::str(self.config_name.clone())),
            ("pes", Json::num(self.pes as f64)),
            ("buffer_mb", Json::num(self.buffer_mb as f64)),
            ("dataflow", Json::str(self.dataflow.clone())),
            ("tile_i", Json::num(self.tile_i as f64)),
            ("tile_j", Json::num(self.tile_j as f64)),
            ("total_cycles", Json::num(self.result.total_cycles as f64)),
            ("throughput_seq_s", Json::num(self.throughput_seq_s)),
            ("energy_mj_per_seq", Json::num(self.energy_mj_per_seq)),
            ("area_mm2", Json::num(self.area_mm2)),
            (
                "compute_stalls",
                Json::num(self.result.stalls.compute_total() as f64),
            ),
            (
                "memory_stalls",
                Json::num(self.result.stalls.memory_total() as f64),
            ),
            ("mac_utilization", Json::num(self.result.mac_utilization)),
            ("on_frontier", Json::Bool(on_frontier)),
        ])
    }
}

/// The full outcome of a sweep.
#[derive(Clone, Debug)]
pub struct DseReport {
    pub model: String,
    pub seq: usize,
    pub batch: usize,
    pub sparsity_source: String,
    pub base: String,
    /// Points in expansion-index order.
    pub points: Vec<DsePoint>,
    pub frontier: ParetoFrontier,
    /// Sweep-level cache statistic.  Scheduling-dependent (workers race
    /// to first-compute a shape), so it is *excluded* from [`to_json`]
    /// — including it would break the byte-identical-across-worker-
    /// counts determinism contract.
    pub cache_hits: usize,
}

impl DseReport {
    pub fn frontier_points(&self) -> impl Iterator<Item = &DsePoint> {
        self.frontier.indices.iter().map(move |&i| &self.points[i])
    }

    pub fn knee_point(&self) -> Option<&DsePoint> {
        self.frontier.knee.map(|i| &self.points[i])
    }

    /// Deterministic serialization: points in index order, frontier as
    /// an index list, object keys sorted by the writer.  No timings, no
    /// thread counts, no cache statistics (see [`DseReport::cache_hits`]).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("model", Json::str(self.model.clone())),
            ("seq", Json::num(self.seq as f64)),
            ("batch", Json::num(self.batch as f64)),
            ("sparsity_source", Json::str(self.sparsity_source.clone())),
            ("base", Json::str(self.base.clone())),
            (
                "points",
                Json::arr(
                    self.points
                        .iter()
                        .map(|p| p.to_json(self.frontier.contains(p.index))),
                ),
            ),
            (
                "frontier",
                Json::arr(self.frontier.indices.iter().map(|&i| Json::num(i as f64))),
            ),
            (
                "knee",
                match self.frontier.knee {
                    Some(i) => Json::num(i as f64),
                    None => Json::Null,
                },
            ),
        ])
    }

    pub fn save(&self, path: impl AsRef<std::path::Path>) -> Result<()> {
        let path = path.as_ref();
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir).ok();
        }
        std::fs::write(path, self.to_json().to_string_pretty())
            .with_context(|| format!("writing DSE report to {}", path.display()))
    }
}

/// Evaluate every point of `space` on the cycle-accurate engine and
/// reduce to a Pareto frontier.
///
/// Work-stealing: scoped workers pull point indices from a shared
/// atomic counter, so a straggler config (say 512 PEs on BERT-Base)
/// does not serialize the tail the way static chunking would.  Each
/// worker forks the engine on the shared op graph (built once — batch
/// and sequence length are sweep-wide constants) and writes its result
/// into the slot owned by the point's index; a shape-keyed cache
/// de-duplicates repeated hardware shapes.  See the module docs for
/// why this is bit-deterministic regardless of worker count.
pub fn sweep(
    space: &DseSpace,
    model: &TransformerConfig,
    seq: usize,
    policy: Policy,
    source: &SparsitySource,
    opts: &SweepOptions,
) -> DseReport {
    let configs = space.expand();
    let total = configs.len();
    let mut report = DseReport {
        model: model.name.clone(),
        seq,
        batch: space.base.batch,
        sparsity_source: source.name().to_string(),
        base: space.base.name.clone(),
        points: Vec::with_capacity(total),
        frontier: ParetoFrontier { indices: Vec::new(), knee: None },
        cache_hits: 0,
    };
    if total == 0 {
        return report;
    }

    let graph = OpGraph::build(model, space.base.batch, seq);
    let threads = resolve_threads(opts, total);
    let next = AtomicUsize::new(0);
    let done = AtomicUsize::new(0);
    let results: Mutex<Vec<Option<SimResult>>> = Mutex::new(vec![None; total]);
    let cache: Mutex<HashMap<SimKey, SimResult>> = Mutex::new(HashMap::new());
    let cache_hits = AtomicUsize::new(0);
    let stride = (total / 10).max(1);

    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= total {
                    break;
                }
                let point = &configs[i];
                let key = SimKey::of(point);
                let cached = cache.lock().unwrap().get(&key).cloned();
                let mut result = match cached {
                    Some(hit) => {
                        cache_hits.fetch_add(1, Ordering::Relaxed);
                        hit
                    }
                    None => {
                        let r = Engine::with_source(point.cfg.clone(), &graph, policy, source)
                            .run();
                        // Two workers may race to first-simulate a
                        // shape; both compute the identical result, so
                        // last-write-wins is harmless.
                        cache.lock().unwrap().insert(key, r.clone());
                        r
                    }
                };
                // The cache is keyed on hardware shape only; stamp the
                // point's own name so drill-down stays unambiguous.
                result.config_name = point.cfg.name.clone();
                results.lock().unwrap()[i] = Some(result);
                let n = done.fetch_add(1, Ordering::Relaxed) + 1;
                if opts.progress && (n % stride == 0 || n == total) {
                    eprintln!("dse: {n}/{total} points simulated");
                }
            });
        }
    });

    let results = results.into_inner().unwrap();
    for (cfgp, slot) in configs.iter().zip(results) {
        let result = slot.expect("worker left a sweep slot empty");
        let area = AreaBreakdown::compute(&cfgp.cfg).total_mm2();
        report.points.push(DsePoint {
            index: cfgp.index,
            config_name: cfgp.cfg.name.clone(),
            pes: cfgp.pes,
            buffer_mb: cfgp.buffer_mb,
            dataflow: cfgp.cfg.dataflow.compact_name(),
            tile_i: cfgp.tile_i,
            tile_j: cfgp.tile_j,
            throughput_seq_s: result.throughput_seq_s(&cfgp.cfg),
            energy_mj_per_seq: result.energy_mj_per_seq(),
            area_mm2: area,
            result,
        });
    }
    let objs: Vec<Objectives> = report.points.iter().map(DsePoint::objectives).collect();
    report.frontier = ParetoFrontier::compute(&objs);
    report.cache_hits = cache_hits.load(Ordering::Relaxed);
    if opts.progress {
        eprintln!(
            "dse: frontier {} / {} points ({} cache hits, {} workers)",
            report.frontier.indices.len(),
            total,
            report.cache_hits,
            threads
        );
    }
    report
}

// ---------------------------------------------------------------------------
// Tests
// ---------------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::engine::SparsityProfile;

    fn o(t: f64, e: f64, a: f64) -> Objectives {
        Objectives { throughput: t, energy: e, area: a }
    }

    #[test]
    fn dominance_basics() {
        let better = o(10.0, 1.0, 5.0);
        let worse = o(8.0, 2.0, 6.0);
        assert!(dominates(&better, &worse));
        assert!(!dominates(&worse, &better));
        // Irreflexive: equal on all axes → no strict improvement.
        assert!(!dominates(&better, &better));
        // Trade-off: neither dominates.
        let fast_big = o(10.0, 1.0, 9.0);
        let slow_small = o(5.0, 1.0, 2.0);
        assert!(!dominates(&fast_big, &slow_small));
        assert!(!dominates(&slow_small, &fast_big));
        // Weak dominance with one strict axis still dominates.
        let same_speed_smaller = o(10.0, 1.0, 4.0);
        assert!(dominates(&same_speed_smaller, &better));
    }

    #[test]
    fn frontier_of_handcrafted_points() {
        let objs = vec![
            o(10.0, 1.0, 5.0), // frontier (fastest)
            o(8.0, 2.0, 6.0),  // dominated by 0
            o(5.0, 0.5, 5.0),  // frontier (least energy)
            o(4.0, 0.6, 4.0),  // frontier (smallest)
            o(4.0, 0.7, 4.5),  // dominated by 3
        ];
        let f = ParetoFrontier::compute(&objs);
        assert_eq!(f.indices, vec![0, 2, 3]);
        assert!(f.knee.is_some());
        assert!(f.contains(f.knee.unwrap()));
        for &i in &f.indices {
            assert_eq!(frontier_gap(&objs, i), 0.0);
        }
        assert!(frontier_gap(&objs, 1) > 0.0);
    }

    #[test]
    fn knee_prefers_balanced_point() {
        // One extreme on each axis plus a balanced point near the ideal
        // corner: the knee must pick the balanced one.
        let objs = vec![
            o(10.0, 10.0, 10.0), // fastest, but worst energy/area
            o(1.0, 1.0, 1.0),    // cheapest, but slowest
            o(9.0, 2.0, 2.0),    // balanced
        ];
        let f = ParetoFrontier::compute(&objs);
        assert_eq!(f.indices, vec![0, 1, 2]);
        assert_eq!(f.knee, Some(2));
    }

    #[test]
    fn empty_sweep_is_empty_frontier() {
        let f = ParetoFrontier::compute(&[]);
        assert!(f.indices.is_empty());
        assert_eq!(f.knee, None);

        let mut space = DseSpace::around(AcceleratorConfig::edge());
        space.pes.clear();
        let report = sweep(
            &space,
            &TransformerConfig::bert_tiny(),
            64,
            Policy::Staggered,
            &SparsitySource::Uniform(SparsityProfile::paper_default()),
            &SweepOptions::default(),
        );
        assert!(report.points.is_empty());
        assert!(report.frontier.indices.is_empty());
    }

    #[test]
    fn expand_is_deterministic_cross_product() {
        let mut space = DseSpace::around(AcceleratorConfig::edge());
        space.pes = vec![32, 64];
        space.buffers_mb = vec![10, 13];
        space.dataflows = vec![Dataflow::parse("bijk").unwrap(), Dataflow::parse("kjib").unwrap()];
        let pts = space.expand();
        assert_eq!(pts.len(), 8);
        for (i, p) in pts.iter().enumerate() {
            assert_eq!(p.index, i);
        }
        // pes outermost, then buffers, then dataflows.
        assert_eq!((pts[0].pes, pts[0].buffer_mb), (32, 10));
        assert_eq!((pts[3].pes, pts[3].buffer_mb), (32, 13));
        assert_eq!((pts[4].pes, pts[4].buffer_mb), (64, 10));
        assert_eq!(pts[0].cfg.name, "acceltran-edge-p32-b10-bijk-t16x16");
        // Repeated expansion is identical.
        let again = space.expand();
        for (a, b) in pts.iter().zip(&again) {
            assert_eq!(a.cfg.name, b.cfg.name);
        }
    }

    #[test]
    fn expanded_edge_point_is_the_preset() {
        // The 13 MB knob splits 4:8:1 into exactly the paper's Edge
        // buffers, so the preset is a *member* of its family sweep.
        let edge = AcceleratorConfig::edge();
        let space = DseSpace::around(edge.clone());
        let pts = space.expand();
        assert_eq!(pts.len(), 1);
        let c = &pts[0].cfg;
        assert_eq!(c.pes, edge.pes);
        assert_eq!(c.act_buffer_bytes, edge.act_buffer_bytes);
        assert_eq!(c.weight_buffer_bytes, edge.weight_buffer_bytes);
        assert_eq!(c.mask_buffer_bytes, edge.mask_buffer_bytes);
        assert_eq!(c.dataflow, edge.dataflow);
        // Same for Server's 104 MB = 32 + 64 + 8.
        let server = AcceleratorConfig::server();
        assert_eq!(DseSpace::net_buffer_mb(&server), 104);
        let spts = DseSpace::around(server.clone()).expand();
        assert_eq!(spts[0].cfg.act_buffer_bytes, server.act_buffer_bytes);
        assert_eq!(spts[0].cfg.weight_buffer_bytes, server.weight_buffer_bytes);
        assert_eq!(spts[0].cfg.mask_buffer_bytes, server.mask_buffer_bytes);
    }

    #[test]
    fn sweep_caches_repeated_shapes() {
        let mut space = DseSpace::around(AcceleratorConfig::edge());
        space.pes = vec![16, 16]; // duplicate grid entry → same shape
        let report = sweep(
            &space,
            &TransformerConfig::bert_tiny(),
            32,
            Policy::Staggered,
            &SparsitySource::Uniform(SparsityProfile::paper_default()),
            &SweepOptions { threads: 1, progress: false },
        );
        assert_eq!(report.points.len(), 2);
        assert_eq!(report.cache_hits, 1);
        assert_eq!(
            report.points[0].result.total_cycles,
            report.points[1].result.total_cycles
        );
        // Identical shapes ⇒ one is redundant, so the frontier keeps
        // only the first (the duplicate neither dominates nor is
        // dominated — equal vectors — so both actually stay).
        assert_eq!(
            report.points[0].objectives(),
            report.points[1].objectives()
        );
    }

    #[test]
    fn sweep_report_json_shape() {
        let mut space = DseSpace::around(AcceleratorConfig::edge());
        space.pes = vec![16, 32];
        let report = sweep(
            &space,
            &TransformerConfig::bert_tiny(),
            32,
            Policy::Staggered,
            &SparsitySource::Uniform(SparsityProfile::paper_default()),
            &SweepOptions { threads: 2, progress: false },
        );
        let json = report.to_json();
        let parsed = Json::parse(&json.to_string_pretty()).expect("report JSON parses");
        assert_eq!(parsed.get("points").unwrap().as_arr().unwrap().len(), 2);
        assert!(!parsed.get("frontier").unwrap().as_arr().unwrap().is_empty());
        assert_eq!(parsed.get("sparsity_source").unwrap().as_str(), Some("uniform"));
        // Report must not leak scheduling-dependent fields.
        assert!(parsed.get("cache_hits").is_none());
        assert!(parsed.get("threads").is_none());
    }

    /// Sec. V-C sanity: the paper's Edge config must sit on (or within
    /// [`FRONTIER_EPSILON`] of) the frontier of a sweep around it.
    /// This is a regression net for cost-model edits: a change that
    /// pushes Edge off its own family's frontier by >15 % has broken
    /// the stall/energy balance the paper's Fig. 16 selection rests on.
    #[test]
    fn edge_preset_is_near_its_family_frontier() {
        let mut space = DseSpace::around(AcceleratorConfig::edge());
        space.pes = vec![32, 64, 128];
        space.buffers_mb = vec![10, 13, 16];
        let report = sweep(
            &space,
            &TransformerConfig::bert_tiny(),
            128,
            Policy::Staggered,
            &SparsitySource::Uniform(SparsityProfile::paper_default()),
            &SweepOptions { threads: 0, progress: false },
        );
        let idx = report
            .points
            .iter()
            .position(|p| p.pes == 64 && p.buffer_mb == 13)
            .expect("edge preset point present in its own sweep");
        let objs: Vec<Objectives> = report.points.iter().map(DsePoint::objectives).collect();
        let gap = frontier_gap(&objs, idx);
        assert!(
            gap <= FRONTIER_EPSILON,
            "Edge preset drifted {gap:.3} past its family frontier (epsilon {FRONTIER_EPSILON})"
        );
    }

    /// Server counterpart, at the paper's Server workload scale (batch
    /// 32 keeps the sweep compute-bound, which is exactly why the paper
    /// sizes Server at 512 PEs — at small batch the weight stream
    /// dominates and fewer PEs would look equivalent).
    #[test]
    fn server_preset_is_near_its_family_frontier() {
        let mut space = DseSpace::around(AcceleratorConfig::server());
        space.pes = vec![128, 512];
        let report = sweep(
            &space,
            &TransformerConfig::bert_base(),
            64,
            Policy::Staggered,
            &SparsitySource::Uniform(SparsityProfile::paper_default()),
            &SweepOptions { threads: 0, progress: false },
        );
        let idx = report
            .points
            .iter()
            .position(|p| p.pes == 512)
            .expect("server preset point present in its own sweep");
        let objs: Vec<Objectives> = report.points.iter().map(DsePoint::objectives).collect();
        let gap = frontier_gap(&objs, idx);
        assert!(
            gap <= FRONTIER_EPSILON,
            "Server preset drifted {gap:.3} past its family frontier (epsilon {FRONTIER_EPSILON})"
        );
    }
}
