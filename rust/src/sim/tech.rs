//! 14nm FinFET technology model: per-module area, dynamic energy and
//! leakage constants.
//!
//! The paper obtains these from SystemVerilog RTL synthesized with Design
//! Compiler on a 14nm library, buffers via FinCACTI, and main memory via
//! NVSim/NVMain, then plugs the constants into a Python cycle-accurate
//! simulator.  We perform the same plug-in with constants *back-fitted to
//! the paper's published aggregates* (Table III totals, Fig. 18
//! breakdowns, Table II bandwidths), so regenerating Table III / Fig. 18
//! from these constants reproduces the paper's rows — see the derivations
//! on each constant.  DESIGN.md §Substitutions records this substitution.

use super::config::AcceleratorConfig;

// ---------------------------------------------------------------------------
// Area (mm^2), derived from Edge totals: 55.12 mm^2 split per Fig. 18(a):
// MAC lanes 19.2% over 1024 lanes, softmax 44.7% over 256 modules,
// layer-norm 10.3% over 64 modules, sparsity pre+post 15.1% over 64 PEs,
// "others" (DynaTran + dataflow + DMA control) 10.7% over 64 PEs.
// ---------------------------------------------------------------------------

/// Area of one MAC lane (16 multipliers + adder tree + GeLU), mm^2.
pub const MAC_LANE_AREA_MM2: f64 = 55.12 * 0.192 / 1024.0;
/// Area of one softmax module, mm^2 (dominates: parallel exp + tile sum).
pub const SOFTMAX_AREA_MM2: f64 = 55.12 * 0.447 / 256.0;
/// Area of one layer-norm module, mm^2.
pub const LAYERNORM_AREA_MM2: f64 = 55.12 * 0.103 / 64.0;
/// Pre+post sparsity modules per PE, mm^2.
pub const SPARSITY_AREA_MM2_PER_PE: f64 = 55.12 * 0.151 / 64.0;
/// DynaTran module + dataflow mux + DMA slice per PE, mm^2.
pub const OTHER_AREA_MM2_PER_PE: f64 = 55.12 * 0.107 / 64.0;

/// On-chip SRAM buffer area per MB (FinCACTI-scale 14nm SRAM ~0.35
/// mm^2/Mb incl. periphery => ~2.8 mm^2/MB; buffers are excluded from the
/// paper's compute-area breakdown so this only feeds chip-level summaries).
pub const BUFFER_AREA_MM2_PER_MB: f64 = 2.8;

// ---------------------------------------------------------------------------
// Dynamic energy (pJ), derived from Edge power: PEs draw 3.79 W at 700MHz
// under BERT-Tiny; Fig. 18(b) splits compute power as MAC 39.3%,
// softmax 49.9%, layer-norm + sparsity + rest 10.8%.  At near-full
// utilization: MAC lanes 3.79*0.393 W / (1024 lanes * 0.7e9 cycle/s)
// = 2.08 pJ per lane-cycle = 0.130 pJ per 20-bit MAC (M=16/lane).
// ---------------------------------------------------------------------------

/// Energy of one fixed-point (IL+FL = 20-bit) multiply-accumulate, pJ.
pub const MAC_PJ: f64 = 0.130;
/// Softmax module energy per element processed, pJ.  Calibrated at the
/// *workload* level: on BERT-Tiny (seq 512, batch 4) the softmax modules
/// process ~4.2M elements against ~310M effectual MACs, and Fig. 18(b)
/// reports softmax at 49.9% of compute power vs MAC 39.3% — so each
/// softmax element must cost ~1.27 * (310M/4.2M) * MAC_PJ ~= 12 pJ.
/// The fixed-point exponential unit is genuinely that expensive, which
/// is also why softmax modules take 44.7% of Edge's area (Fig. 18(a)).
pub const SOFTMAX_PJ_PER_ELEM: f64 = 12.0;
/// Layer-norm energy per element, pJ (mean/var/rsqrt/affine; the rsqrt
/// unit dominates — LN modules take 10.3% of area for 64 instances).
pub const LAYERNORM_PJ_PER_ELEM: f64 = 1.0;
/// DynaTran comparator energy per element, pJ (one compare + mask write;
/// the "negligible overhead" claim in silicon terms).
pub const DYNATRAN_PJ_PER_ELEM: f64 = 0.018;
/// Pre/post-compute sparsity module energy per element (AND/XOR gates +
/// zero-collapsing shifter stage), pJ.  Bit-level mask logic: an order
/// of magnitude below a 20-bit MAC, so skipping ineffectual MACs is a
/// clear net win at the tile level (Table IV row 4's 1.9x energy gap).
pub const SPARSITY_PJ_PER_ELEM: f64 = 0.012;
/// On-chip buffer read/write energy per byte, pJ (FinCACTI-scale SRAM;
/// Edge buffer power 0.08 W at BERT-Tiny traffic).
pub const BUFFER_PJ_PER_BYTE: f64 = 0.35;
/// GeLU unit energy per element (piecewise-poly eval at lane output), pJ.
pub const GELU_PJ_PER_ELEM: f64 = 0.12;

// ---------------------------------------------------------------------------
// Leakage (W).  Fig. 17(a) shows leakage is a small fraction thanks to
// power-gating of unused modules; modules leak only while powered on.
// ---------------------------------------------------------------------------

/// Leakage per powered-on MAC lane, W.
pub const MAC_LANE_LEAK_W: f64 = 2.0e-4;
/// Leakage per powered-on softmax module, W.
pub const SOFTMAX_LEAK_W: f64 = 8.0e-4;
/// Leakage per powered-on layer-norm module, W.
pub const LAYERNORM_LEAK_W: f64 = 6.0e-4;
/// Buffer leakage per MB (SRAM cannot be fully gated while holding data).
pub const BUFFER_LEAK_W_PER_MB: f64 = 2.0e-3;

/// Fixed-point element width in bytes (IL=4 + FL=16 bits = 2.5 B).
pub const ELEM_BYTES: f64 = 2.5;

/// Per-design-point area summary (Table III area column + Fig. 18(a)).
#[derive(Clone, Debug)]
pub struct AreaBreakdown {
    pub mac_lanes_mm2: f64,
    pub softmax_mm2: f64,
    pub layernorm_mm2: f64,
    pub sparsity_mm2: f64,
    pub other_mm2: f64,
    pub buffers_mm2: f64,
    pub memory_mm2: f64,
}

impl AreaBreakdown {
    pub fn compute(cfg: &AcceleratorConfig) -> AreaBreakdown {
        // Area counts physical modules (LP mode gates them but they exist).
        let lanes = cfg.pes * cfg.mac_lanes_per_pe;
        let smx = cfg.pes * cfg.softmax_per_pe;
        let ln = cfg.pes * cfg.layernorm_per_pe;
        let mb = cfg.total_buffer_bytes() as f64 / (1 << 20) as f64;
        AreaBreakdown {
            mac_lanes_mm2: lanes as f64 * MAC_LANE_AREA_MM2,
            softmax_mm2: smx as f64 * SOFTMAX_AREA_MM2,
            layernorm_mm2: ln as f64 * LAYERNORM_AREA_MM2,
            sparsity_mm2: cfg.pes as f64 * SPARSITY_AREA_MM2_PER_PE,
            other_mm2: cfg.pes as f64 * OTHER_AREA_MM2_PER_PE,
            buffers_mm2: mb * BUFFER_AREA_MM2_PER_MB,
            // monolithic-3D RRAM stacks above the logic tier (two memory
            // tiers, Sec. IV-B) — zero footprint; DRAM is off-chip.
            memory_mm2: 0.0,
        }
    }

    /// Compute-logic area (the paper's Fig. 18a universe).
    pub fn compute_mm2(&self) -> f64 {
        self.mac_lanes_mm2
            + self.softmax_mm2
            + self.layernorm_mm2
            + self.sparsity_mm2
            + self.other_mm2
    }

    /// Total die area including buffers.
    pub fn total_mm2(&self) -> f64 {
        self.compute_mm2() + self.buffers_mm2 + self.memory_mm2
    }
}

/// Stillmaker–Baas-style technology scaling of throughput/energy between
/// nodes, used to normalize baseline platforms to 14nm (Sec. IV-C).
/// Returns (delay_scale, energy_scale) to convert *from* `from_nm` *to*
/// 14nm: divide latency by `delay_scale`, divide energy by `energy_scale`.
pub fn scale_to_14nm(from_nm: f64) -> (f64, f64) {
    // Inverter-delay and switching-energy proxies; near-linear in feature
    // size over 28..7nm per the scaling-equations paper.
    let delay = from_nm / 14.0;
    let energy = (from_nm / 14.0).powi(2);
    (delay, energy)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::config::AcceleratorConfig;

    #[test]
    fn edge_compute_area_matches_fig18() {
        let a = AreaBreakdown::compute(&AcceleratorConfig::edge());
        let total = a.compute_mm2();
        assert!((total - 55.12).abs() < 0.5, "total {total:.2}");
        // Fig. 18(a) shares must be reproduced by construction.
        assert!((a.mac_lanes_mm2 / total - 0.192).abs() < 0.01);
        assert!((a.softmax_mm2 / total - 0.447).abs() < 0.01);
        assert!((a.layernorm_mm2 / total - 0.103).abs() < 0.01);
    }

    #[test]
    fn server_area_is_paper_scale() {
        // Table III: 1950.95 mm^2 for Server.  Our per-module constants
        // must land within 25% (Server's softmax/PE ratio differs from
        // Edge, so exact equality is not expected).
        let a = AreaBreakdown::compute(&AcceleratorConfig::server());
        let total = a.compute_mm2();
        assert!(
            (1400.0..2500.0).contains(&total),
            "server compute area {total:.0} mm^2"
        );
    }

    #[test]
    fn mac_energy_reproduces_edge_pe_power() {
        // 1024 lanes * 16 MACs * 0.7 GHz * MAC_PJ ~= 3.79 W * 39.3%.
        let w = 1024.0 * 16.0 * 0.7e9 * MAC_PJ * 1e-12;
        assert!((w - 3.79 * 0.393).abs() < 0.1, "w {w:.2}");
    }

    #[test]
    fn softmax_energy_reproduces_fig18b_share() {
        // Workload-level calibration: BERT-Tiny @ seq 512, batch 4.
        // softmax elements: layers * heads * batch * seq^2
        let smx_elems = 2.0 * 2.0 * 4.0 * 512.0 * 512.0;
        // effectual MACs: ~1.24G dense * 0.25 effectual at the paper's
        // 50%/50% operating point
        let eff_macs = 1.24e9 * 0.25;
        let ratio =
            (smx_elems * SOFTMAX_PJ_PER_ELEM) / (eff_macs * MAC_PJ);
        // Fig. 18(b): softmax 49.9% vs MAC 39.3% -> ratio ~1.27
        assert!(
            (0.9..1.7).contains(&ratio),
            "softmax/MAC energy ratio {ratio:.2} (paper ~1.27)"
        );
    }

    #[test]
    fn scaling_to_14nm() {
        let (d, e) = scale_to_14nm(28.0);
        assert!((d - 2.0).abs() < 1e-9);
        assert!((e - 4.0).abs() < 1e-9);
        let (d14, e14) = scale_to_14nm(14.0);
        assert_eq!((d14, e14), (1.0, 1.0));
    }
}
