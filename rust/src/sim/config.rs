//! Accelerator design-point configuration (paper Table II).
//!
//! One [`AcceleratorConfig`] captures everything the engine needs to
//! price a run: compute provisioning (PEs, MAC lanes, softmax /
//! layer-norm modules), the three on-chip buffers, the main-memory
//! technology ([`MemoryKind`]: LP-DDR3 for Edge, monolithic-3D RRAM for
//! Server — the Table IV memory ablation swaps them), tile shape and
//! dataflow, clock, and the ablation switches (`dynatran_enabled`,
//! `sparsity_modules`, `low_power`) behind Table III's LP mode and
//! Table IV's rows.  The three presets — `edge`, `server`, `edge_lp` —
//! are the paper's design points; `acceltran config --preset …` prints
//! any of them with the Table III area/power summary, and
//! `acceltran sweep` perturbs PEs/buffers around them for the Fig. 16
//! stall surface.

use super::dataflow::Dataflow;

/// Main-memory technology (Table II: LP-DDR3 for Edge, monolithic-3D
/// RRAM for Server; Table IV ablates Server onto DRAM).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MemoryKind {
    /// 1-channel LP-DDR3-1600: 25.6 GB/s.
    LpDdr3,
    /// 2-channel monolithic-3D RRAM: 256 GB/s, lower retrieval latency.
    Mono3dRram,
}

impl MemoryKind {
    /// Peak bandwidth in bytes per second.
    pub fn bandwidth_bytes_per_s(self) -> f64 {
        match self {
            MemoryKind::LpDdr3 => 25.6e9,
            MemoryKind::Mono3dRram => 256.0e9,
        }
    }

    /// First-word access latency in accelerator cycles @700 MHz.
    /// LP-DDR3 ~50 ns ≈ 35 cycles; monolithic-3D RRAM sits on inter-tier
    /// vias directly above the logic tier, ~10 ns ≈ 7 cycles.
    pub fn latency_cycles(self) -> u64 {
        match self {
            MemoryKind::LpDdr3 => 35,
            MemoryKind::Mono3dRram => 7,
        }
    }

    /// Access energy (pJ per byte), from the NVSim/NVMain-derived power
    /// rows of Table III (see `tech` for the derivation).
    pub fn energy_pj_per_byte(self) -> f64 {
        match self {
            MemoryKind::LpDdr3 => 113.7,
            MemoryKind::Mono3dRram => 144.0,
        }
    }

    /// Idle (background) power in watts — charged while the simulation
    /// is running regardless of traffic.
    pub fn idle_power_w(self) -> f64 {
        match self {
            MemoryKind::LpDdr3 => 0.10,
            MemoryKind::Mono3dRram => 1.20,
        }
    }
}

/// One AccelTran design point (Table II row).
#[derive(Clone, Debug)]
pub struct AcceleratorConfig {
    pub name: String,
    /// Number of processing elements.
    pub pes: usize,
    /// MAC lanes per PE.
    pub mac_lanes_per_pe: usize,
    /// Softmax modules per PE.
    pub softmax_per_pe: usize,
    /// Layer-norm modules per PE (1 in both paper design points; Fig. 18
    /// lists 64 LN modules for the 64-PE Edge).
    pub layernorm_per_pe: usize,
    /// Multipliers per MAC lane (M; paper fixes M=16).
    pub multipliers_per_lane: usize,
    /// Elements processed per cycle by a softmax / layer-norm module.
    pub special_elems_per_cycle: usize,
    /// Activation buffer bytes.
    pub act_buffer_bytes: usize,
    /// Weight buffer bytes.
    pub weight_buffer_bytes: usize,
    /// Mask buffer bytes.
    pub mask_buffer_bytes: usize,
    pub memory: MemoryKind,
    /// Inference batch size.
    pub batch: usize,
    /// Clock in Hz (700 MHz for both design points).
    pub clock_hz: f64,
    /// Tile sizes along b, i(=x), j(=z): paper sets (1, 16, 16); the k
    /// tile equals the MAC-lane depth.
    pub tile_b: usize,
    pub tile_i: usize,
    pub tile_j: usize,
    pub tile_k: usize,
    /// Loop-unrolling order for tile issue.
    pub dataflow: Dataflow,
    /// Dynamic pruning at runtime (Table IV ablation: "w/o DynaTran").
    pub dynatran_enabled: bool,
    /// Pre/post-compute sparsity modules present (Table IV: "w/o
    /// Sparsity-aware modules" computes densely even on pruned data).
    pub sparsity_modules: bool,
    /// Low-power mode: only half the compute hardware active at a time
    /// (Table III "LP mode").
    pub low_power: bool,
    /// Steady-state serving: word/position embeddings are already
    /// resident in the weight buffer ("these load operations only occur
    /// once and subsequent transformer evaluations reuse these
    /// embeddings", Sec. V-D).  Disable to simulate the cold first batch
    /// (the 51K-cycle load phase of Fig. 17(b)).
    pub embeddings_resident: bool,
}

impl AcceleratorConfig {
    /// AccelTran-Edge (Table II).
    pub fn edge() -> Self {
        AcceleratorConfig {
            name: "acceltran-edge".into(),
            pes: 64,
            mac_lanes_per_pe: 16,
            softmax_per_pe: 4,
            layernorm_per_pe: 1,
            multipliers_per_lane: 16,
            special_elems_per_cycle: 16,
            act_buffer_bytes: 4 << 20,
            weight_buffer_bytes: 8 << 20,
            mask_buffer_bytes: 1 << 20,
            memory: MemoryKind::LpDdr3,
            batch: 4,
            clock_hz: 700.0e6,
            tile_b: 1,
            tile_i: 16,
            tile_j: 16,
            tile_k: 16,
            dataflow: Dataflow::BIJK,
            dynatran_enabled: true,
            sparsity_modules: true,
            low_power: false,
            embeddings_resident: true,
        }
    }

    /// AccelTran-Server (Table II).
    pub fn server() -> Self {
        AcceleratorConfig {
            name: "acceltran-server".into(),
            pes: 512,
            mac_lanes_per_pe: 32,
            softmax_per_pe: 32,
            layernorm_per_pe: 1,
            multipliers_per_lane: 16,
            special_elems_per_cycle: 16,
            act_buffer_bytes: 32 << 20,
            weight_buffer_bytes: 64 << 20,
            mask_buffer_bytes: 8 << 20,
            memory: MemoryKind::Mono3dRram,
            batch: 32,
            clock_hz: 700.0e6,
            tile_b: 1,
            tile_i: 16,
            tile_j: 16,
            tile_k: 16,
            dataflow: Dataflow::BIJK,
            dynatran_enabled: true,
            sparsity_modules: true,
            low_power: false,
            embeddings_resident: true,
        }
    }

    /// Edge low-power mode (Table III third row): half the compute
    /// hardware power-gated at any time.
    pub fn edge_lp() -> Self {
        let mut c = Self::edge();
        c.name = "acceltran-edge-lp".into();
        c.low_power = true;
        c
    }

    pub fn preset(name: &str) -> Option<Self> {
        match name {
            "edge" | "acceltran-edge" => Some(Self::edge()),
            "server" | "acceltran-server" => Some(Self::server()),
            "edge-lp" | "acceltran-edge-lp" => Some(Self::edge_lp()),
            _ => None,
        }
    }

    /// Total MAC lanes (scaled down in LP mode, which gates half).
    pub fn total_mac_lanes(&self) -> usize {
        let n = self.pes * self.mac_lanes_per_pe;
        if self.low_power { n / 2 } else { n }
    }

    /// Total softmax modules.
    pub fn total_softmax(&self) -> usize {
        let n = self.pes * self.softmax_per_pe;
        if self.low_power { n / 2 } else { n }
    }

    /// Total layer-norm modules.
    pub fn total_layernorm(&self) -> usize {
        let n = self.pes * self.layernorm_per_pe;
        if self.low_power { n / 2 } else { n }
    }

    /// Theoretical peak ops/s (Table III TOP/s column): every multiplier
    /// plus every softmax/LN element-slot busy every cycle.
    pub fn peak_ops_per_s(&self) -> f64 {
        let per_cycle = self.total_mac_lanes() * self.multipliers_per_lane
            + self.total_softmax() * self.special_elems_per_cycle
            + self.total_layernorm() * self.special_elems_per_cycle;
        per_cycle as f64 * self.clock_hz
    }

    /// Net on-chip buffer bytes.
    pub fn total_buffer_bytes(&self) -> usize {
        self.act_buffer_bytes + self.weight_buffer_bytes + self.mask_buffer_bytes
    }

    /// Cycles -> seconds.
    pub fn cycles_to_s(&self, cycles: u64) -> f64 {
        cycles as f64 / self.clock_hz
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn edge_matches_table_ii() {
        let e = AcceleratorConfig::edge();
        assert_eq!(e.pes, 64);
        assert_eq!(e.total_mac_lanes(), 1024);
        assert_eq!(e.total_softmax(), 256);
        assert_eq!(e.act_buffer_bytes, 4 << 20);
        assert_eq!(e.batch, 4);
    }

    #[test]
    fn server_matches_table_ii() {
        let s = AcceleratorConfig::server();
        assert_eq!(s.pes, 512);
        assert_eq!(s.total_mac_lanes(), 16384);
        assert_eq!(s.total_softmax(), 16384);
        assert_eq!(s.memory, MemoryKind::Mono3dRram);
        assert_eq!(s.batch, 32);
    }

    #[test]
    fn peak_tops_match_table_iii() {
        // Table III: Edge 15.05 TOP/s, Server 372.74 TOP/s, Edge-LP 7.52.
        let edge = AcceleratorConfig::edge().peak_ops_per_s() / 1e12;
        assert!((edge - 15.05).abs() < 0.1, "edge {edge:.2}");
        let server = AcceleratorConfig::server().peak_ops_per_s() / 1e12;
        assert!((server - 372.74).abs() < 1.0, "server {server:.2}");
        let lp = AcceleratorConfig::edge_lp().peak_ops_per_s() / 1e12;
        assert!((lp - 7.52).abs() < 0.1, "lp {lp:.2}");
    }

    #[test]
    fn lp_mode_halves_resources() {
        let e = AcceleratorConfig::edge();
        let lp = AcceleratorConfig::edge_lp();
        assert_eq!(lp.total_mac_lanes() * 2, e.total_mac_lanes());
        assert_eq!(lp.total_softmax() * 2, e.total_softmax());
    }

    #[test]
    fn memory_kinds_differ() {
        assert!(
            MemoryKind::Mono3dRram.bandwidth_bytes_per_s()
                > 5.0 * MemoryKind::LpDdr3.bandwidth_bytes_per_s()
        );
        assert!(
            MemoryKind::Mono3dRram.latency_cycles()
                < MemoryKind::LpDdr3.latency_cycles()
        );
    }
}
