//! `acceltran` — CLI for the AccelTran reproduction.
//!
//! Subcommands:
//!   ops       print the Table I op inventory for a model
//!   memreq    Fig. 1 memory-requirement breakdown
//!   config    show an accelerator preset (Table II) + Table III summary
//!   simulate  cycle-accurate simulation of a model on a design point
//!   sweep     design-space exploration (Fig. 16 stall surface)
//!   dse       parallel trace-driven design-space exploration: PE ×
//!             buffer × dataflow grid reduced to a throughput/energy/
//!             area Pareto frontier (`sim::dse`, Sec. V-C)
//!   dataflow  compare the 24 dataflows on a matmul (Fig. 15)
//!   train     train the synthetic model through the runtime
//!             (--task classify|span; span is the Fig. 14(b) fine-tune)
//!   serve     concurrent serving over a worker pool with deadline-aware
//!             batching (optionally sim-in-the-loop costed); with
//!             --listen, an HTTP/JSON front-end over sharded pools with
//!             graceful drain and a live /stats endpoint; with
//!             --span-params, a second span model rides alongside the
//!             classifier (multi-model: /v1/classify + /v1/span)
//!   eval      accuracy/sparsity sweep (Figs. 11/12; --task span gives
//!             the Fig. 14(b) F1-vs-sparsity sweep)
//!   trace     capture a measured sparsity trace and run the simulator
//!             on it (the trace-driven Figs. 17-20 pipeline; --task span
//!             captures over the span eval set)
//!
//! The functional subcommands (train/serve/eval) run on the pure-Rust
//! reference backend out of the box; set `ACCELTRAN_BACKEND=pjrt` (with
//! artifacts present) to dispatch to the AOT/PJRT path instead.

use std::time::Duration;

use acceltran::coordinator::{
    self, ModelEntry, ServeConfig, ServePool, SimInLoop, TaskKind,
};
use acceltran::model::{memreq::MemReq, OpGraph, TransformerConfig};
use acceltran::nlp::sentiment::SentimentTask;
use acceltran::nlp::span::SpanTask;
use acceltran::runtime::{ParamStore, Runtime};
use acceltran::serve::net::{
    install_drain_signals, Limits, NetConfig, NetServer,
};
use acceltran::sim::engine::{simulate, SparsityProfile};
use acceltran::sim::scheduler::Policy;
use acceltran::sim::tech::AreaBreakdown;
use acceltran::sim::{dataflow, dse, tiling, AcceleratorConfig, SparsitySource};
use acceltran::util::cli::Args;
use acceltran::util::table::{eng, Table};
use anyhow::{anyhow, Result};

fn main() {
    let args = Args::from_env(true);
    let result = match args.subcommand.as_deref() {
        Some("ops") => cmd_ops(&args),
        Some("memreq") => cmd_memreq(&args),
        Some("config") => cmd_config(&args),
        Some("simulate") => cmd_simulate(&args),
        Some("sweep") => cmd_sweep(&args),
        Some("dse") => cmd_dse(&args),
        Some("dataflow") => cmd_dataflow(&args),
        Some("train") => cmd_train(&args),
        Some("serve") => cmd_serve(&args),
        Some("eval") => cmd_eval(&args),
        Some("trace") => cmd_trace(&args),
        _ => {
            print_usage();
            Ok(())
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn print_usage() {
    println!(
        "acceltran — sparsity-aware transformer accelerator simulator\n\
         \n\
         usage: acceltran <subcommand> [--options]\n\
         \n\
         subcommands:\n\
           ops       --model bert-tiny [--batch 1 --seq 128]\n\
           memreq    --model bert-base [--weight-sparsity 0.5]\n\
           config    --preset edge|server|edge-lp\n\
           simulate  --preset edge --model bert-tiny [--seq 128]\n\
                     [--act-sparsity 0.5 --weight-sparsity 0.5]\n\
                     [--no-dynatran --no-sparsity-modules --policy equal]\n\
           sweep     --model bert-tiny [--seq 128]\n\
           dse       [--trace reports/sparsity_trace.json]\n\
                     [--pes 32,64,128,256 --buffers 10,13,16]\n\
                     [--dataflows all|bijk,bikj,... --tiles 16x16,8x32]\n\
                     [--preset edge --model bert-tiny --seq 128]\n\
                     [--threads N --out reports/dse_frontier.json]\n\
           dataflow  [--m 64 --k 64 --n 64 --lanes 4]\n\
           train     [--task classify|span --steps 200 --lr 1e-3]\n\
                     [--examples 4096 --save path]\n\
           serve     [--task classify|span --requests 256 --tau 0.04]\n\
                     [--workers 4 --slo-ms 25]\n\
                     [--batch-slo-ms 100 --max-queue 1024]\n\
                     [--params path --report reports/serve_report.json]\n\
                     [--sim-in-loop --preset edge --model bert-tiny\n\
                      --sim-seq 128 --sim-trace reports/sparsity_trace.json]\n\
                     [--listen 127.0.0.1:8080 --pools 2 --max-batch 32\n\
                      --read-timeout-ms 2000 --max-body-kb 1024\n\
                      --addr-file path]  (HTTP mode; drain via SIGTERM;\n\
                      queue-full submits get 429 + Retry-After)\n\
                     [--span-params path]  (HTTP mode: also serve a span\n\
                      model as 'span' next to 'classify' — /v1/span)\n\
           eval      [--task classify|span --taus 0,0.02,0.05]\n\
                     [--examples 512 --params path]\n\
           trace     [--task classify|span --tau 0.04 --examples 512]\n\
                     [--params path]\n\
                     [--out reports/sparsity_trace.json --no-sim]\n\
                     [--preset edge --model bert-tiny --seq 128]\n\
         \n\
         train/serve/eval/trace execute on the pure-Rust reference\n\
         backend by default; ACCELTRAN_BACKEND=pjrt|reference overrides."
    );
}

fn model_from(args: &Args) -> Result<TransformerConfig> {
    let name = args.get_or("model", "bert-tiny");
    TransformerConfig::preset(name)
        .ok_or_else(|| anyhow!("unknown model '{name}' (bert-tiny|bert-mini|bert-base)"))
}

fn preset_from(args: &Args) -> Result<AcceleratorConfig> {
    let name = args.get_or("preset", "edge");
    AcceleratorConfig::preset(name)
        .ok_or_else(|| anyhow!("unknown preset '{name}' (edge|server|edge-lp)"))
}

fn cmd_ops(args: &Args) -> Result<()> {
    let model = model_from(args)?;
    let batch = args.get_usize("batch", 1);
    let seq = args.get_usize("seq", 128);
    let g = OpGraph::build(&model, batch, seq);
    g.validate().map_err(|e| anyhow!(e))?;
    println!(
        "{} @ batch={batch} seq={seq}: {} ops, {} dense MACs",
        model.name,
        g.nodes.len(),
        eng(g.total_macs() as f64)
    );
    let mut t = Table::new(["op", "kind", "dims", "flops"]);
    for n in g.nodes.iter().take(args.get_usize("limit", 30)) {
        t.row([
            n.label.clone(),
            format!("{:?}", n.kind),
            format!("{:?}", n.dims),
            eng(n.dims.flops() as f64),
        ]);
    }
    t.print();
    Ok(())
}

fn cmd_memreq(args: &Args) -> Result<()> {
    let model = model_from(args)?;
    let ws = args.get_f64("weight-sparsity", 0.5);
    let batch = args.get_usize("batch", 1);
    let seq = args.get_usize("seq", model.seq);
    let mr = MemReq::compute(&model, batch, seq, ws);
    println!(
        "{} @ batch={batch} seq={seq} weight-sparsity={ws}: act/weight ratio {:.2}x",
        model.name,
        mr.act_to_weight_ratio()
    );
    let mb = |b: f64| format!("{:.2}", b / (1 << 20) as f64);
    let mut t = Table::new(["component", "MB"]);
    t.row(["embeddings".to_string(), mb(mr.embedding_bytes)]);
    t.row(["weights (compressed)".to_string(), mb(mr.weight_bytes)]);
    t.row(["activations".to_string(), mb(mr.activation_bytes)]);
    t.row(["main memory (emb+w)".to_string(), mb(mr.main_memory_bytes())]);
    t.print();
    Ok(())
}

fn cmd_config(args: &Args) -> Result<()> {
    let cfg = preset_from(args)?;
    let area = AreaBreakdown::compute(&cfg);
    println!("{} (Table II / Table III):", cfg.name);
    let mut t = Table::new(["parameter", "value"]);
    t.row(["PEs".to_string(), cfg.pes.to_string()]);
    t.row(["MAC lanes".to_string(), cfg.total_mac_lanes().to_string()]);
    t.row(["softmax modules".to_string(), cfg.total_softmax().to_string()]);
    t.row([
        "layer-norm modules".to_string(),
        cfg.total_layernorm().to_string(),
    ]);
    t.row(["batch".to_string(), cfg.batch.to_string()]);
    t.row([
        "memory".to_string(),
        format!(
            "{:?} ({} GB/s)",
            cfg.memory,
            cfg.memory.bandwidth_bytes_per_s() / 1e9
        ),
    ]);
    t.row([
        "buffers (act/w/mask MB)".to_string(),
        format!(
            "{}/{}/{}",
            cfg.act_buffer_bytes >> 20,
            cfg.weight_buffer_bytes >> 20,
            cfg.mask_buffer_bytes >> 20
        ),
    ]);
    t.row([
        "peak TOP/s".to_string(),
        format!("{:.2}", cfg.peak_ops_per_s() / 1e12),
    ]);
    t.row([
        "compute area mm^2".to_string(),
        format!("{:.2}", area.compute_mm2()),
    ]);
    t.print();
    Ok(())
}

fn sparsity_from(args: &Args) -> SparsityProfile {
    SparsityProfile {
        weight_rho: args.get_f64("weight-sparsity", 0.5),
        act_rho: args.get_f64("act-sparsity", 0.5),
        inherent_act_rho: args.get_f64("inherent-sparsity", 0.1),
    }
}

fn cmd_simulate(args: &Args) -> Result<()> {
    let mut cfg = preset_from(args)?;
    let model = model_from(args)?;
    let seq = args.get_usize("seq", 128);
    if args.has("no-dynatran") {
        cfg.dynatran_enabled = false;
    }
    if args.has("no-sparsity-modules") {
        cfg.sparsity_modules = false;
    }
    if let Some(df) = args.get("dataflow") {
        cfg.dataflow = dataflow::Dataflow::parse(df)
            .ok_or_else(|| anyhow!("bad dataflow '{df}'"))?;
    }
    if let Some(p) = args.get("pes") {
        cfg.pes = p.parse()?;
    }
    let policy = if args.get_or("policy", "staggered") == "equal" {
        Policy::EqualPriority
    } else {
        Policy::Staggered
    };
    let sp = sparsity_from(args);
    let r = simulate(&cfg, &model, seq, policy, sp);
    println!("{}", r.to_json(&cfg).to_string_pretty());
    Ok(())
}

fn cmd_sweep(args: &Args) -> Result<()> {
    let model = model_from(args)?;
    let seq = args.get_usize("seq", 128);
    let sp = sparsity_from(args);
    let mut t = Table::new([
        "PEs", "buffer MB", "compute stalls", "memory stalls", "cycles",
    ]);
    for &pes in &[32usize, 64, 128, 256] {
        for &buf_mb in &[10usize, 13, 16] {
            let mut cfg = AcceleratorConfig::edge();
            cfg.pes = pes;
            // 4:8:1 split of the net buffer (Sec. V-C)
            let unit = (buf_mb << 20) / 13;
            cfg.act_buffer_bytes = 4 * unit;
            cfg.weight_buffer_bytes = 8 * unit;
            cfg.mask_buffer_bytes = unit;
            let r = simulate(&cfg, &model, seq, Policy::Staggered, sp);
            t.row([
                pes.to_string(),
                buf_mb.to_string(),
                eng(r.stalls.compute_total() as f64),
                eng(r.stalls.memory_total() as f64),
                eng(r.total_cycles as f64),
            ]);
        }
    }
    t.print();
    Ok(())
}

fn parse_usize_list(s: &str, flag: &str) -> Result<Vec<usize>> {
    s.split(',')
        .map(str::trim)
        .filter(|t| !t.is_empty())
        .map(|t| {
            t.parse::<usize>()
                .map_err(|_| anyhow!("--{flag}: bad number '{t}'"))
        })
        .collect()
}

/// `dse`: the parallel trace-driven design-space exploration (Sec. V-C)
/// — expands a PE × buffer × dataflow (× tiling) grid around a preset,
/// sweeps it on worker threads against a measured sparsity trace (or
/// the uniform assumed profile when no capture exists), and reduces to
/// a throughput/energy/area Pareto frontier with a knee-point pick.
fn cmd_dse(args: &Args) -> Result<()> {
    let base = preset_from(args)?;
    let model = model_from(args)?;
    let seq = args.get_usize("seq", 128);
    let policy = if args.get_or("policy", "staggered") == "equal" {
        Policy::EqualPriority
    } else {
        Policy::Staggered
    };

    let mut space = dse::DseSpace::around(base);
    space.pes = parse_usize_list(args.get_or("pes", "32,64,128,256"), "pes")?;
    space.buffers_mb =
        parse_usize_list(args.get_or("buffers", "10,13,16"), "buffers")?;
    let dfs = args.get_or("dataflows", "all");
    space.dataflows = if dfs == "all" {
        dataflow::Dataflow::all()
    } else {
        dfs.split(',')
            .map(str::trim)
            .filter(|t| !t.is_empty())
            .map(|t| {
                dataflow::Dataflow::parse(t)
                    .ok_or_else(|| anyhow!("--dataflows: bad dataflow '{t}'"))
            })
            .collect::<Result<Vec<_>>>()?
    };
    if let Some(tiles) = args.get("tiles") {
        space.tiles = tiles
            .split(',')
            .map(str::trim)
            .filter(|t| !t.is_empty())
            .map(|t| {
                let (i, j) = t
                    .split_once('x')
                    .ok_or_else(|| anyhow!("--tiles: expected IxJ, got '{t}'"))?;
                Ok((
                    i.parse().map_err(|_| anyhow!("--tiles: bad tile '{t}'"))?,
                    j.parse().map_err(|_| anyhow!("--tiles: bad tile '{t}'"))?,
                ))
            })
            .collect::<Result<Vec<_>>>()?;
    }

    // Sparsity source: prefer a measured PR-4 capture.  A trace that
    // exists but fails to load is an error (the user thinks they are
    // sweeping on measured sparsity); only a *missing* file falls back.
    let trace_path = args.get_or("trace", "reports/sparsity_trace.json");
    let source = match acceltran::trace::SparsityTrace::load(trace_path) {
        Ok(t) => {
            println!("dse: measured trace {trace_path}");
            SparsitySource::Trace(t)
        }
        Err(e) if std::path::Path::new(trace_path).exists() => {
            return Err(e.context(format!("loading trace {trace_path}")));
        }
        Err(_) => {
            println!(
                "dse: uniform fallback profile (no trace at {trace_path}; \
                 run `acceltran trace` to capture one)"
            );
            SparsitySource::Uniform(SparsityProfile::paper_default())
        }
    };

    let opts = dse::SweepOptions {
        threads: args.get_usize("threads", 0),
        progress: true,
    };
    println!(
        "dse: sweeping {} points ({} PEs x {} buffers x {} dataflows x {} tiles) \
         of {} on {} @ seq {seq}",
        space.len(),
        space.pes.len(),
        space.buffers_mb.len(),
        space.dataflows.len(),
        space.tiles.len(),
        space.base.name,
        model.name,
    );
    let report = dse::sweep(&space, &model, seq, policy, &source, &opts);

    let mut t = Table::new([
        "frontier point",
        "PEs",
        "buf MB",
        "dataflow",
        "seq/s",
        "mJ/seq",
        "mm^2",
    ]);
    for p in report.frontier_points() {
        let marker = if report.frontier.knee == Some(p.index) {
            format!("{} <- knee", p.config_name)
        } else {
            p.config_name.clone()
        };
        t.row([
            marker,
            p.pes.to_string(),
            p.buffer_mb.to_string(),
            p.dataflow.clone(),
            eng(p.throughput_seq_s),
            format!("{:.3}", p.energy_mj_per_seq),
            format!("{:.1}", p.area_mm2),
        ]);
    }
    t.print();
    if let Some(knee) = report.knee_point() {
        println!(
            "knee point: {} ({} seq/s, {:.3} mJ/seq, {:.1} mm^2)",
            knee.config_name,
            eng(knee.throughput_seq_s),
            knee.energy_mj_per_seq,
            knee.area_mm2
        );
    }
    let out = args.get_or("out", "reports/dse_frontier.json");
    report.save(out)?;
    println!(
        "wrote {out} ({} points, {} on the frontier)",
        report.points.len(),
        report.frontier.indices.len()
    );
    Ok(())
}

fn cmd_dataflow(args: &Args) -> Result<()> {
    let m = args.get_usize("m", 64);
    let k = args.get_usize("k", 64);
    let n = args.get_usize("n", 64);
    let lanes = args.get_usize("lanes", 4);
    let grid = tiling::tile_matmul(m, k, n, 1, 16, 16, 16);
    let mut t = Table::new(["dataflow", "reuse instances", "dyn energy (nJ)"]);
    for df in dataflow::Dataflow::all() {
        let r = dataflow::replay(
            df,
            &grid,
            lanes,
            acceltran::sim::tech::BUFFER_PJ_PER_BYTE * acceltran::sim::tech::ELEM_BYTES,
            acceltran::sim::tech::MAC_PJ,
        );
        t.row([
            r.dataflow_name.clone(),
            r.reuse_instances().to_string(),
            format!("{:.2}", r.dynamic_energy_pj / 1e3),
        ]);
    }
    t.print();
    Ok(())
}

/// Parse the `--task` flag shared by train/serve/eval/trace.
fn task_from(args: &Args) -> Result<TaskKind> {
    let name = args.get_or("task", "classify");
    TaskKind::parse(name)
        .ok_or_else(|| anyhow!("unknown task '{name}' (classify|span)"))
}

fn cmd_train(args: &Args) -> Result<()> {
    let mut rt = Runtime::load_default()?;
    let vocab = rt.manifest.vocab;
    let seq = rt.manifest.seq;
    let task_kind = task_from(args)?;
    let steps = args.get_usize("steps", 200);
    let lr = args.get_f64("lr", 1e-3) as f32;
    let n = args.get_usize("examples", 4096);
    let mut store = ParamStore::init(&rt.manifest, args.get_u64("seed", 0));
    println!(
        "training {} ({} params) on synthetic {}: {} examples, {} steps \
         ['{}' backend]",
        rt.manifest.model_name,
        rt.manifest.param_count,
        task_kind.name(),
        n,
        steps,
        rt.backend_name()
    );
    let log = match task_kind {
        TaskKind::Classify => {
            let task =
                SentimentTask::new(vocab, seq, args.get_u64("task-seed", 7));
            let train_ds = task.dataset(n, 1);
            let val_ds = task.dataset(512, 2);
            coordinator::train(
                &mut rt, &mut store, &train_ds, Some(&val_ds), steps, lr, 50,
                true,
            )?
        }
        TaskKind::Span => {
            let task = SpanTask::new(vocab, seq);
            let train_ds = task.dataset(n, 1);
            let val_ds = task.dataset(512, 2);
            coordinator::train_span(
                &mut rt, &mut store, &train_ds, Some(&val_ds), steps, lr, 50,
                true,
            )?
        }
    };
    let (head, tail) = log.head_tail_means(10);
    println!("loss: first-10 mean {head:.4} -> last-10 mean {tail:.4}");
    if let Some(path) = args.get("save") {
        store.save(path)?;
        println!("saved params to {path}");
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    if args.get("listen").is_some() {
        return cmd_serve_net(args);
    }
    let rt = Runtime::load_default()?;
    let vocab = rt.manifest.vocab;
    let seq = rt.manifest.seq;
    let n = args.get_usize("requests", 256);
    let tau = args.get_f64("tau", 0.04) as f32;
    let workers = args.get_usize("workers", 4);
    let slo = Duration::from_millis(args.get_u64("slo-ms", 25));
    let params = match args.get("params") {
        Some(p) => ParamStore::from_file(&rt.manifest, p)?.params,
        None => ParamStore::init(&rt.manifest, 0).params,
    };
    // sim-in-the-loop: cost every dispatched batch shape on the
    // cycle-accurate engine, preferring a measured trace (PR-4 pipeline)
    let sim = if args.has("sim-in-loop") {
        let accel = preset_from(args)?;
        let model = model_from(args)?;
        let sim_seq = args.get_usize("sim-seq", 128);
        let trace_path = args.get_or("sim-trace", "reports/sparsity_trace.json");
        let source = match acceltran::trace::SparsityTrace::load(trace_path) {
            Ok(t) => {
                println!("sim-in-the-loop: measured trace {trace_path}");
                SparsitySource::Trace(t)
            }
            // a trace that exists but fails to load is an error, not a
            // silent fallback — the user thinks they are simulating on
            // measured sparsity
            Err(e) if std::path::Path::new(trace_path).exists() => {
                return Err(e.context(format!("loading sim trace {trace_path}")));
            }
            Err(_) => {
                println!(
                    "sim-in-the-loop: uniform fallback profile (no trace at \
                     {trace_path}; run `acceltran trace` to capture one)"
                );
                SparsitySource::Uniform(SparsityProfile::paper_default())
            }
        };
        Some(SimInLoop { accel, model, seq: sim_seq, source })
    } else {
        None
    };
    let task_kind = task_from(args)?;
    println!(
        "serving {n} {} requests on {workers} worker(s), slo {slo:?}, \
         tau {tau} ['{}' backend]",
        task_kind.name(),
        rt.backend_name()
    );
    // synthesize the request wave before the pool starts: wall time (and
    // the reported req/s) must measure serving, not dataset generation
    let request_rows: Vec<Vec<i32>> = match task_kind {
        TaskKind::Classify => {
            let task = SentimentTask::new(vocab, seq, 7);
            task.dataset(n, 3).examples.into_iter().map(|e| e.ids).collect()
        }
        TaskKind::Span => {
            let task = SpanTask::new(vocab, seq);
            task.dataset(n, 3).examples.into_iter().map(|e| e.ids).collect()
        }
    };
    let cfg = ServeConfig {
        workers,
        slo,
        sim,
        batch_slo: Duration::from_millis(args.get_u64("batch-slo-ms", 100)),
        max_queue: args
            .get_usize("max-queue", coordinator::DEFAULT_MAX_QUEUE),
    };
    let pool = match task_kind {
        TaskKind::Classify => ServePool::start(&rt, &params, &cfg)?,
        TaskKind::Span => ServePool::start_multi(
            vec![ModelEntry {
                name: "span".to_string(),
                task: TaskKind::Span,
                runtime: rt.fork()?,
                params,
                sim: cfg.sim.clone(),
            }],
            &cfg,
        )?,
    };
    for ids in &request_rows {
        // offline driver: on backpressure, wait for the pool to drain a
        // little instead of shedding (the HTTP front-end answers 429)
        loop {
            match pool.submit(ids.clone(), tau) {
                Ok(_) => break,
                Err(coordinator::SubmitError::QueueFull { .. }) => {
                    std::thread::sleep(Duration::from_millis(1));
                }
                Err(e) => return Err(e.into()),
            }
        }
    }
    let (report, _responses) = pool.finish()?;
    report.print_summary();
    let path = args.get_or("report", "reports/serve_report.json");
    report.save(path)?;
    println!("wrote {path}");
    Ok(())
}

/// `serve --listen ADDR`: the HTTP/JSON front-end — sharded pools
/// behind a hand-rolled HTTP/1.1 server, drained gracefully on
/// SIGTERM / ctrl-c (see `acceltran::serve::net`).
fn cmd_serve_net(args: &Args) -> Result<()> {
    let rt = Runtime::load_default()?;
    let params = match args.get("params") {
        Some(p) => ParamStore::from_file(&rt.manifest, p)?.params,
        None => ParamStore::init(&rt.manifest, 0).params,
    };
    let pools = args.get_usize("pools", 2);
    let workers = args.get_usize("workers", 2);
    let slo = Duration::from_millis(args.get_u64("slo-ms", 25));
    let read_timeout = args.get_duration_ms("read-timeout-ms", 2000);
    let limits = Limits {
        read_timeout,
        // whole-request wall clock scales with the per-read knob so one
        // flag tunes both; 4x leaves room for legitimately slow links
        max_request_time: read_timeout * 4,
        max_body_bytes: args.get_usize("max-body-kb", 1024) * 1024,
        ..Limits::default()
    };
    let cfg = NetConfig {
        listen: args.get_or("listen", "127.0.0.1:8080").to_string(),
        pools,
        serve: ServeConfig {
            workers,
            slo,
            sim: None,
            batch_slo: Duration::from_millis(
                args.get_u64("batch-slo-ms", 100),
            ),
            max_queue: args
                .get_usize("max-queue", coordinator::DEFAULT_MAX_QUEUE),
        },
        limits,
        default_tau: args.get_f64("tau", 0.04) as f32,
        max_batch: args.get_usize("max-batch", 32),
        drain_on_signal: true,
    };
    install_drain_signals();
    // --span-params registers a second model: the server becomes
    // multi-model, serving "classify" and "span" side by side (the
    // batcher never mixes them in one dispatch)
    let server = match args.get("span-params") {
        Some(sp) => {
            let span_params = ParamStore::from_file(&rt.manifest, sp)?.params;
            let entries = vec![
                ModelEntry {
                    name: "classify".to_string(),
                    task: TaskKind::Classify,
                    runtime: rt.fork()?,
                    params,
                    sim: None,
                },
                ModelEntry {
                    name: "span".to_string(),
                    task: TaskKind::Span,
                    runtime: rt.fork()?,
                    params: span_params,
                    sim: None,
                },
            ];
            println!("multi-model: classify + span ({sp})");
            NetServer::start_multi(entries, &cfg)?
        }
        None => NetServer::start(&rt, &params, &cfg)?,
    };
    println!(
        "listening on http://{} — {pools} pool(s) x {workers} worker(s), \
         slo {slo:?} ['{}' backend]",
        server.addr(),
        rt.backend_name()
    );
    // external drivers (the CI smoke job) read the resolved address
    // from here when the listen port was 0
    if let Some(path) = args.get("addr-file") {
        if let Some(dir) = std::path::Path::new(path).parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, server.addr().to_string())?;
        println!("wrote bound address to {path}");
    }
    println!("drain with ctrl-c or SIGTERM");
    let report = server.run_until_drained()?;
    report.print_summary();
    let path = args.get_or("report", "reports/net_report.json");
    report.save(path)?;
    println!("wrote {path}");
    Ok(())
}

fn cmd_trace(args: &Args) -> Result<()> {
    let tau = args.get_f64("tau", 0.04) as f32;
    let out = args.get_or("out", "reports/sparsity_trace.json").to_string();
    let mut rt = Runtime::load_default()?;
    println!("trace backend: {}", rt.backend_name());
    let examples = args.get_usize(
        "examples",
        acceltran::util::cli::env_usize("ACCELTRAN_EVAL_EXAMPLES", 512),
    );
    let task_kind = task_from(args)?;
    let store = match (args.get("params"), task_kind) {
        (Some(p), _) => ParamStore::from_file(&rt.manifest, p)?,
        (None, TaskKind::Classify) => coordinator::trainer::ensure_trained(
            &mut rt,
            std::path::Path::new("reports/trained_params.bin"),
            args.get_usize("steps", 200),
            true,
        )?,
        (None, TaskKind::Span) => coordinator::trainer::ensure_trained_span(
            &mut rt,
            std::path::Path::new("reports/trained_span_params.bin"),
            args.get_usize("steps", 200),
            true,
        )?,
    };
    // same shared eval set the fig benches capture over; scope the host
    // tiled-GEMM accumulator to the capture so the block-sparsity line
    // below describes exactly this run
    acceltran::runtime::tensor::gemm_stats_reset();
    let trace = match task_kind {
        TaskKind::Classify => {
            coordinator::measured_trace_with(&mut rt, &store, tau, examples)?
        }
        TaskKind::Span => {
            // the span counterpart of the shared eval-set contract:
            // dataset variant 2 of the synthetic span task
            let task = SpanTask::new(rt.manifest.vocab, rt.manifest.seq);
            let ds = task.dataset(examples, 2);
            coordinator::capture_trace_span(
                &mut rt,
                &store.params,
                &ds,
                tau,
                examples,
            )?
        }
    };
    let gemm = acceltran::runtime::tensor::gemm_stats_snapshot();

    println!(
        "\ncaptured over {} examples at tau={tau}: mean act sparsity {:.3}, \
         inherent {:.3}, {} {:.4}",
        trace.examples,
        trace.mean_act_rho(),
        trace.inherent_act_rho,
        if task_kind == TaskKind::Span { "span F1" } else { "accuracy" },
        trace.eval_accuracy
    );
    println!(
        "host gemm (blocked path): effectual tiles {:.3}, effectual MACs \
         {:.3}, tile-skipped MACs {:.3} of {}",
        gemm.effectual_tile_fraction(),
        gemm.effectual_mac_fraction(),
        gemm.tile_skipped_mac_fraction(),
        gemm.macs
    );
    let mut t = Table::new([
        "layer", "input", "q", "k", "v", "scores", "context", "proj", "ffn_in",
        "gelu", "ffn_out",
    ]);
    for (i, l) in trace.layers.iter().enumerate() {
        t.row([
            i.to_string(),
            format!("{:.3}", l.input),
            format!("{:.3}", l.q),
            format!("{:.3}", l.k),
            format!("{:.3}", l.v),
            format!("{:.3}", l.scores),
            format!("{:.3}", l.context),
            format!("{:.3}", l.proj_out),
            format!("{:.3}", l.ffn_in),
            format!("{:.3}", l.gelu),
            format!("{:.3}", l.ffn_out),
        ]);
    }
    t.print();
    trace.save(&out)?;
    println!("wrote {out}");

    if !args.has("no-sim") {
        // hand the measured trace to the cycle-accurate engine
        let cfg = preset_from(args)?;
        let model = model_from(args)?;
        let seq = args.get_usize("seq", 128);
        let source = acceltran::sim::SparsitySource::Trace(trace);
        let r = acceltran::sim::simulate_with(
            &cfg,
            &model,
            seq,
            Policy::Staggered,
            &source,
        );
        println!(
            "\ntrace-driven simulation ({} x {} @ seq={seq}):",
            cfg.name, model.name
        );
        println!("{}", r.to_json(&cfg).to_string_pretty());
    }
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let mut rt = Runtime::load_default()?;
    let vocab = rt.manifest.vocab;
    let seq = rt.manifest.seq;
    let examples = args.get_usize("examples", 512);
    let taus: Vec<f32> = args
        .get_or("taus", "0,0.01,0.02,0.03,0.04,0.06,0.08,0.1")
        .split(',')
        .map(|s| s.trim().parse().unwrap())
        .collect();
    let params = match args.get("params") {
        Some(p) => ParamStore::from_file(&rt.manifest, p)?.params,
        None => {
            println!("(untrained params — pass --params for a trained model)");
            ParamStore::init(&rt.manifest, 0).params
        }
    };
    let (curve, metric) = match task_from(args)? {
        TaskKind::Classify => {
            let task = SentimentTask::new(vocab, seq, 7);
            let ds = task.dataset(examples, 2);
            (
                coordinator::sweep_dynatran(
                    &mut rt, &params, &ds, &taus, examples,
                )?,
                "accuracy",
            )
        }
        TaskKind::Span => {
            let task = SpanTask::new(vocab, seq);
            let ds = task.dataset(examples, 2);
            (
                coordinator::sweep_dynatran_span(
                    &mut rt, &params, &ds, &taus, examples,
                )?,
                "span F1",
            )
        }
    };
    let mut t = Table::new(["tau", "act sparsity", metric]);
    for p in &curve.points {
        t.row([
            format!("{:.3}", p.knob),
            format!("{:.3}", p.activation_sparsity),
            format!("{:.4}", p.accuracy),
        ]);
    }
    t.print();
    Ok(())
}
