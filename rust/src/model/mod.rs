//! Transformer architecture descriptions shared by the simulator and the
//! functional runtime: configuration presets, the Table I memory/compute
//! op inventory, Fig. 1 memory-requirement analytics, and the op-graph
//! builder that the control block schedules.

pub mod config;
pub mod memreq;
pub mod ops;

pub use config::TransformerConfig;
pub use ops::{OpGraph, OpKind, OpNode, TraceClass};
