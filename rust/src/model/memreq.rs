//! Fig. 1 analytics: memory requirements of a transformer, split into
//! embeddings, weights, and activations.
//!
//! The paper stores weights/activations in (IL + FL)-bit fixed point
//! (IL=4, FL=16 → 20 bits, padded to 2.5 bytes in buffer lines); Fig. 1's
//! headline numbers (52.8 MB BERT-Tiny, 3.4 GB BERT-Base) follow from the
//! element counts in [`TransformerConfig`] at the paper's operating point.

use super::TransformerConfig;

/// Bits per stored element (IL + FL).
pub const IL_BITS: usize = 4;
pub const FL_BITS: usize = 16;
pub const ELEM_BITS: usize = IL_BITS + FL_BITS;

/// Bytes for `elems` fixed-point elements (bit-packed).
pub fn fixed_bytes(elems: usize) -> f64 {
    (elems * ELEM_BITS) as f64 / 8.0
}

/// Memory requirement breakdown for one model (Fig. 1 bars).
#[derive(Clone, Debug)]
pub struct MemReq {
    pub model: String,
    pub embedding_bytes: f64,
    pub weight_bytes: f64,
    pub activation_bytes: f64,
    /// Batch/sequence the activation figure was computed at.
    pub batch: usize,
    pub seq: usize,
}

impl MemReq {
    /// Compute the breakdown at batch size `batch`, sequence length `seq`,
    /// with an optional static weight-sparsity ratio (the paper quotes its
    /// main-memory numbers at a conservative 50% weight sparsity, which
    /// halves stored weights under the mask encoding minus mask overhead).
    pub fn compute(
        cfg: &TransformerConfig,
        batch: usize,
        seq: usize,
        weight_sparsity: f64,
    ) -> MemReq {
        assert!((0.0..=1.0).contains(&weight_sparsity));
        let emb = fixed_bytes(cfg.embedding_params());
        let dense_w = fixed_bytes(cfg.weight_params());
        // Binary-mask compressed storage: non-zeros + 1 bit/elem mask.
        let w = dense_w * (1.0 - weight_sparsity)
            + cfg.weight_params() as f64 / 8.0;
        let act = fixed_bytes(cfg.activation_elems(batch, seq));
        MemReq {
            model: cfg.name.clone(),
            embedding_bytes: emb,
            weight_bytes: w,
            activation_bytes: act,
            batch,
            seq,
        }
    }

    /// Main-memory requirement: embeddings + weights (activations live in
    /// on-chip buffers at runtime) — the "minimum main memory" column of
    /// Table III.
    pub fn main_memory_bytes(&self) -> f64 {
        self.embedding_bytes + self.weight_bytes
    }

    /// Activation-to-weight ratio quoted in Sec. II-A2 (8.98x for
    /// BERT-Tiny, 2.06x for BERT-Base at their operating points).
    pub fn act_to_weight_ratio(&self) -> f64 {
        self.activation_bytes / self.weight_bytes
    }
}

/// Megabytes helper.
pub fn mb(bytes: f64) -> f64 {
    bytes / (1024.0 * 1024.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bert_tiny_main_memory_scale() {
        // Paper Table III quotes 52.88 MB for BERT-Tiny embeddings+weights;
        // that figure is fp32-based with serving state.  Our 20-bit
        // fixed-point encoder-only count is internally consistent instead:
        // embeddings ~9.9 MB + compressed weights ~0.6 MB.
        let cfg = TransformerConfig::bert_tiny();
        let mr = MemReq::compute(&cfg, 1, cfg.seq, 0.5);
        let got = mb(mr.main_memory_bytes());
        assert!((8.0..16.0).contains(&got), "got {got:.1} MB");
        // embeddings dominate Tiny's footprint — the Fig. 1(a) message.
        assert!(mr.embedding_bytes > 5.0 * mr.weight_bytes);
    }

    #[test]
    fn bert_base_main_memory_is_much_larger() {
        // Fig. 1(b): for BERT-Base, weights overtake embeddings and the
        // total is ~17x BERT-Tiny's (at the same element width).
        let tiny = MemReq::compute(&TransformerConfig::bert_tiny(), 1, 512, 0.5);
        let base = MemReq::compute(&TransformerConfig::bert_base(), 1, 512, 0.5);
        let ratio = base.main_memory_bytes() / tiny.main_memory_bytes();
        assert!(ratio > 10.0, "ratio {ratio:.1}");
        assert!(mb(base.main_memory_bytes()) > 100.0);
        assert!(base.weight_bytes > base.embedding_bytes);
    }

    #[test]
    fn activation_ratios_match_fig1_ordering() {
        let tiny = MemReq::compute(&TransformerConfig::bert_tiny(), 1, 512, 0.0);
        let base = MemReq::compute(&TransformerConfig::bert_base(), 1, 512, 0.0);
        assert!(tiny.act_to_weight_ratio() > base.act_to_weight_ratio());
        assert!(tiny.act_to_weight_ratio() > 4.0);
        assert!(base.act_to_weight_ratio() > 1.0);
    }

    #[test]
    fn weight_sparsity_halves_weight_storage() {
        let cfg = TransformerConfig::bert_tiny();
        let dense = MemReq::compute(&cfg, 1, 128, 0.0);
        let sparse = MemReq::compute(&cfg, 1, 128, 0.5);
        let ratio = sparse.weight_bytes / dense.weight_bytes;
        assert!((0.5..0.6).contains(&ratio), "ratio {ratio:.3}");
    }
}
