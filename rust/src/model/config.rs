//! Transformer architecture configuration (paper Sec. II-A / IV-A).

/// Shape of an encoder-only transformer, in the paper's notation:
/// hidden dimension `h`, `l` encoder layers, `n` attention heads per
/// layer, feed-forward dimension (4h for the BERT family), vocabulary and
/// maximum sequence length.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TransformerConfig {
    pub name: String,
    pub hidden: usize,
    pub layers: usize,
    pub heads: usize,
    pub ff: usize,
    pub vocab: usize,
    pub seq: usize,
}

impl TransformerConfig {
    /// BERT-Tiny (Turc et al.): h=128, 2 layers, 2 heads.  The paper's
    /// edge-side evaluation model.
    pub fn bert_tiny() -> Self {
        TransformerConfig {
            name: "bert-tiny".into(),
            hidden: 128,
            layers: 2,
            heads: 2,
            ff: 512,
            vocab: 30_522,
            seq: 512,
        }
    }

    /// BERT-Mini: h=256, 4 layers, 4 heads (Fig. 13 second model).
    pub fn bert_mini() -> Self {
        TransformerConfig {
            name: "bert-mini".into(),
            hidden: 256,
            layers: 4,
            heads: 4,
            ff: 1024,
            vocab: 30_522,
            seq: 512,
        }
    }

    /// BERT-Base: h=768, 12 layers, 12 heads.  The paper's server-side
    /// evaluation model.
    pub fn bert_base() -> Self {
        TransformerConfig {
            name: "bert-base".into(),
            hidden: 768,
            layers: 12,
            heads: 12,
            ff: 3072,
            vocab: 30_522,
            seq: 512,
        }
    }

    /// The synthetic-task model exported by `python/compile/aot.py`
    /// (BERT-Tiny shape on the synthetic vocabulary; see DESIGN.md
    /// §Substitutions).
    pub fn bert_tiny_synth(vocab: usize, seq: usize) -> Self {
        TransformerConfig {
            name: "bert-tiny-synth".into(),
            hidden: 128,
            layers: 2,
            heads: 2,
            ff: 512,
            vocab,
            seq,
        }
    }

    /// Look up a preset by name.
    pub fn preset(name: &str) -> Option<Self> {
        match name {
            "bert-tiny" => Some(Self::bert_tiny()),
            "bert-mini" => Some(Self::bert_mini()),
            "bert-base" => Some(Self::bert_base()),
            _ => None,
        }
    }

    /// Per-head dimension h/n.
    pub fn head_dim(&self) -> usize {
        debug_assert_eq!(self.hidden % self.heads, 0);
        self.hidden / self.heads
    }

    /// Weight parameters of one encoder layer (QKV + output projection +
    /// FFN + layer-norm affine), the quantity Fig. 1 calls "weights".
    pub fn layer_weight_params(&self) -> usize {
        let h = self.hidden;
        let attn = 4 * h * h + 4 * h; // wq,wk,wv,wo + biases
        let ffn = 2 * h * self.ff + self.ff + h;
        let ln = 4 * h; // two layer-norms, gamma+beta each
        attn + ffn + ln
    }

    /// Total weight parameters across all encoder layers.
    pub fn weight_params(&self) -> usize {
        self.layers * self.layer_weight_params()
    }

    /// Word + position embedding parameters (M-OP-0 inputs).
    pub fn embedding_params(&self) -> usize {
        (self.vocab + self.seq) * self.hidden
    }

    /// Activation elements produced by one forward pass at batch size `b`
    /// and sequence length `s` — every intermediate matrix of Table I
    /// (the quantity that dominates Fig. 1's activation bars).
    pub fn activation_elems(&self, batch: usize, seq: usize) -> usize {
        let h = self.hidden;
        let n = self.heads;
        let per_layer =
            // input H + Q,K,V + per-head scores A and probs S + P + MHA out
            seq * h          // H entering the layer
            + 3 * seq * h    // Q, K, V (all heads concatenated)
            + 2 * n * seq * seq // A_i and S_i per head
            + seq * h        // P (concat heads)
            + seq * h        // H^MHA
            + seq * h        // H^LN
            + seq * self.ff  // H^F1
            + seq * h        // H^F2
            + seq * h; // H^O
        batch * (self.layers * per_layer + seq * h) // + embedding output
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_have_paper_shapes() {
        let tiny = TransformerConfig::bert_tiny();
        assert_eq!((tiny.hidden, tiny.layers, tiny.heads), (128, 2, 2));
        let base = TransformerConfig::bert_base();
        assert_eq!((base.hidden, base.layers, base.heads), (768, 12, 12));
        assert_eq!(base.head_dim(), 64);
    }

    #[test]
    fn preset_lookup() {
        assert!(TransformerConfig::preset("bert-tiny").is_some());
        assert!(TransformerConfig::preset("gpt-17t").is_none());
    }

    #[test]
    fn bert_base_param_count_is_close_to_110m() {
        // BERT-Base is famously ~110M parameters; embeddings + encoder
        // weights here (no pooler) should land in [100M, 115M].
        let base = TransformerConfig::bert_base();
        let total = base.weight_params() + base.embedding_params();
        assert!(
            (100_000_000..115_000_000).contains(&total),
            "got {total}"
        );
    }

    #[test]
    fn activation_to_weight_ratio_larger_for_tiny() {
        // Fig. 1: activations/weights = 8.98x for BERT-Tiny vs 2.06x for
        // BERT-Base — the ratio must be substantially larger for Tiny.
        let tiny = TransformerConfig::bert_tiny();
        let base = TransformerConfig::bert_base();
        let r_tiny =
            tiny.activation_elems(1, tiny.seq) as f64 / tiny.weight_params() as f64;
        let r_base =
            base.activation_elems(1, base.seq) as f64 / base.weight_params() as f64;
        assert!(r_tiny > 2.0 * r_base, "tiny {r_tiny:.2} base {r_base:.2}");
    }
}
