//! Table I op inventory: the memory-load (M-OP) and compute (C-OP)
//! operation stream of an encoder-only transformer, with dependencies.
//!
//! This is the input language of the AccelTran control block: the
//! scheduler tiles each op (`sim::tiling`), orders tiles under a dataflow
//! (`sim::dataflow`), and issues them to PEs/softmax/layer-norm modules
//! while honouring the dependency edges declared here.

use super::TransformerConfig;

/// What kind of hardware resource an op occupies.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum OpKind {
    /// M-OP: DMA a weight/embedding matrix into the weight buffer.
    MemLoad,
    /// Matrix multiplication on MAC lanes (blue ops of Table I).
    MatMul,
    /// Softmax module (green, C-OP-5).
    Softmax,
    /// Layer-norm module (orange, C-OP-8/11).
    LayerNorm,
    /// Elementwise residual add executed on MAC lanes' adders.
    Add,
}

/// Stable functional identity of an op, shared by the functional half
/// (trace capture hooks in `runtime::backend::reference`) and the timing
/// half (per-op sparsity resolution in `sim::engine`).
///
/// Labels like `"l0.h1.C-OP-4.qkt"` are human-facing; the *class* is the
/// machine-facing key a [`crate::trace::SparsityTrace`] is resolved
/// against, derived from the label's final dot-segment (which is part of
/// the op-graph contract and covered by tests below).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum TraceClass {
    /// M-OP-0: word + position embedding load.
    Embedding,
    /// M-OP-1..3: fused Q/K/V weight load.
    WqkvLoad,
    /// M-OP-4: attention output-projection weight load.
    WoLoad,
    /// M-OP-5: first feed-forward weight load.
    Wf1Load,
    /// M-OP-6: second feed-forward weight load.
    Wf2Load,
    /// C-OP-1..3: Q/K/V projections (weight x hidden-state input).
    Qkv,
    /// C-OP-4: attention scores Q K^T (activation x activation).
    AttnScore,
    /// C-OP-5: softmax over score rows.
    Softmax,
    /// C-OP-6: context S V (dense probabilities x pruned values).
    AttnContext,
    /// C-OP-7: per-head output projection.
    AttnProj,
    /// C-OP-8: post-attention residual add + layer-norm.
    AddNorm1,
    /// C-OP-11: post-FFN residual add + layer-norm.
    AddNorm2,
    /// C-OP-9: first feed-forward matmul (GeLU fused on its output).
    Ffn1,
    /// C-OP-10: second feed-forward matmul (consumes post-GeLU acts).
    Ffn2,
    /// Forward-compatibility catch-all for labels this inventory does
    /// not know; resolves to the trace's mean sparsity.
    Other,
}

/// One node of the transformer op graph.
#[derive(Clone, Debug)]
pub struct OpNode {
    /// Stable index in the graph.
    pub id: usize,
    /// Table-I-style label, e.g. `"l0.h1.C-OP-4"` or `"M-OP-2"`.
    pub label: String,
    pub kind: OpKind,
    /// Layer index (usize::MAX for the embedding stage).
    pub layer: usize,
    /// Attention head for per-head ops (None for layer-wide ops) — the
    /// stagger scheduler keys its head priorities off this.
    pub head: Option<usize>,
    /// Operand shape (b, x, y) x (b, y, z) for matmuls; (b, x, y) for
    /// elementwise/softmax/layer-norm; bytes for MemLoad is x*y*IL+FL.
    pub dims: OpDims,
    /// Graph predecessors (must complete before this op may issue).
    pub deps: Vec<usize>,
}

impl OpNode {
    /// The op's stable [`TraceClass`], derived from the label's final
    /// dot-segment (e.g. `"l0.h1.C-OP-4.qkt"` -> `AttnScore`).
    pub fn trace_class(&self) -> TraceClass {
        let tail = self.label.rsplit('.').next().unwrap_or("");
        match tail {
            "embeddings" => TraceClass::Embedding,
            "wqkv" => TraceClass::WqkvLoad,
            "wo" => TraceClass::WoLoad,
            "wf1" => TraceClass::Wf1Load,
            "wf2" => TraceClass::Wf2Load,
            "q" | "k" | "v" => TraceClass::Qkv,
            "qkt" => TraceClass::AttnScore,
            "softmax" => TraceClass::Softmax,
            "sv" => TraceClass::AttnContext,
            "proj" => TraceClass::AttnProj,
            "ffn1" => TraceClass::Ffn1,
            "ffn2" => TraceClass::Ffn2,
            "add" | "ln" => {
                if self.label.contains("C-OP-8") {
                    TraceClass::AddNorm1
                } else {
                    TraceClass::AddNorm2
                }
            }
            _ => TraceClass::Other,
        }
    }
}

/// Shapes the scheduler needs to tile an op.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OpDims {
    /// (rows, inner, cols): rows x inner @ inner x cols matmul.
    MatMul { m: usize, k: usize, n: usize },
    /// (rows, cols) elementwise / row-wise op.
    Elem { m: usize, n: usize },
    /// Weight-matrix elements to DMA on-chip.
    Load { elems: usize },
}

impl OpDims {
    /// Number of scalar MAC operations (for MatMul) or element visits.
    pub fn flops(&self) -> usize {
        match *self {
            OpDims::MatMul { m, k, n } => m * k * n,
            OpDims::Elem { m, n } => m * n,
            OpDims::Load { elems } => elems,
        }
    }

    /// Output elements produced.
    pub fn out_elems(&self) -> usize {
        match *self {
            OpDims::MatMul { m, n, .. } => m * n,
            OpDims::Elem { m, n } => m * n,
            OpDims::Load { elems } => elems,
        }
    }
}

/// The full op graph for one forward pass of one input sequence batch.
#[derive(Clone, Debug)]
pub struct OpGraph {
    pub nodes: Vec<OpNode>,
    pub config: TransformerConfig,
    pub batch: usize,
    pub seq: usize,
}

impl OpGraph {
    /// Build the Table I op stream for `cfg` at batch size `batch` and
    /// sequence length `seq`.
    ///
    /// Per layer and per head i: C-OP-1..3 (Q/K/V projections), C-OP-4
    /// (QK^T), C-OP-5 (softmax), C-OP-6 (SV), C-OP-7 (output projection);
    /// then layer-wide C-OP-8 (add+LN), C-OP-9/10 (FFN GeLU matmuls) and
    /// C-OP-11 (LN).  M-OPs load each weight matrix before first use.
    pub fn build(cfg: &TransformerConfig, batch: usize, seq: usize) -> OpGraph {
        let mut g = Builder {
            nodes: Vec::new(),
        };
        let h = cfg.hidden;
        let hd = cfg.head_dim();
        let rows = batch * seq; // token rows processed per matmul

        // M-OP-0: embeddings (word + position) into the weight buffer.
        let emb = g.push(
            "M-OP-0.embeddings",
            OpKind::MemLoad,
            usize::MAX,
            None,
            OpDims::Load { elems: cfg.embedding_params() },
            vec![],
        );

        // The "current hidden state" producer: ops that later layers wait on.
        let mut h_ready = emb;

        for layer in 0..cfg.layers {
            let l = |s: &str| format!("l{layer}.{s}");

            // M-OP-1..4: per-layer attention weights (loaded once, all heads).
            let w_qkv = g.push(
                &l("M-OP-1-3.wqkv"),
                OpKind::MemLoad,
                layer,
                None,
                OpDims::Load { elems: 3 * h * h },
                vec![],
            );
            let w_o = g.push(
                &l("M-OP-4.wo"),
                OpKind::MemLoad,
                layer,
                None,
                OpDims::Load { elems: h * h },
                vec![],
            );

            let mut head_outputs = Vec::with_capacity(cfg.heads);
            for head in 0..cfg.heads {
                let hl = |s: &str| format!("l{layer}.h{head}.{s}");
                // C-OP-1..3: Q/K/V projections for this head (h x hd each).
                let q = g.push(
                    &hl("C-OP-1.q"),
                    OpKind::MatMul,
                    layer,
                    Some(head),
                    OpDims::MatMul { m: rows, k: h, n: hd },
                    vec![h_ready, w_qkv],
                );
                let k = g.push(
                    &hl("C-OP-2.k"),
                    OpKind::MatMul,
                    layer,
                    Some(head),
                    OpDims::MatMul { m: rows, k: h, n: hd },
                    vec![h_ready, w_qkv],
                );
                let v = g.push(
                    &hl("C-OP-3.v"),
                    OpKind::MatMul,
                    layer,
                    Some(head),
                    OpDims::MatMul { m: rows, k: h, n: hd },
                    vec![h_ready, w_qkv],
                );
                // C-OP-4: A = Q K^T (per sequence: batch of seq x seq).
                let a = g.push(
                    &hl("C-OP-4.qkt"),
                    OpKind::MatMul,
                    layer,
                    Some(head),
                    OpDims::MatMul { m: batch * seq, k: hd, n: seq },
                    vec![q, k],
                );
                // C-OP-5: softmax over rows of A.
                let s = g.push(
                    &hl("C-OP-5.softmax"),
                    OpKind::Softmax,
                    layer,
                    Some(head),
                    OpDims::Elem { m: batch * seq, n: seq },
                    vec![a],
                );
                // C-OP-6: P = S V.
                let p = g.push(
                    &hl("C-OP-6.sv"),
                    OpKind::MatMul,
                    layer,
                    Some(head),
                    OpDims::MatMul { m: batch * seq, k: seq, n: hd },
                    vec![s, v],
                );
                // C-OP-7: per-head output projection (hd x hd in the paper's
                // per-head form; concatenation is free in the buffer layout).
                let o = g.push(
                    &hl("C-OP-7.proj"),
                    OpKind::MatMul,
                    layer,
                    Some(head),
                    OpDims::MatMul { m: rows, k: hd, n: hd },
                    vec![p, w_o],
                );
                head_outputs.push(o);
            }

            // C-OP-8: residual add + layer-norm over the concatenated heads.
            let mut add_deps = head_outputs.clone();
            add_deps.push(h_ready);
            let add = g.push(
                &l("C-OP-8.add"),
                OpKind::Add,
                layer,
                None,
                OpDims::Elem { m: rows, n: h },
                add_deps,
            );
            let ln1 = g.push(
                &l("C-OP-8.ln"),
                OpKind::LayerNorm,
                layer,
                None,
                OpDims::Elem { m: rows, n: h },
                vec![add],
            );

            // M-OP-5..6 + C-OP-9..10: feed-forward.
            let w_f1 = g.push(
                &l("M-OP-5.wf1"),
                OpKind::MemLoad,
                layer,
                None,
                OpDims::Load { elems: h * cfg.ff },
                vec![],
            );
            let w_f2 = g.push(
                &l("M-OP-6.wf2"),
                OpKind::MemLoad,
                layer,
                None,
                OpDims::Load { elems: cfg.ff * h },
                vec![],
            );
            let f1 = g.push(
                &l("C-OP-9.ffn1"),
                OpKind::MatMul,
                layer,
                None,
                OpDims::MatMul { m: rows, k: h, n: cfg.ff },
                vec![ln1, w_f1],
            );
            let f2 = g.push(
                &l("C-OP-10.ffn2"),
                OpKind::MatMul,
                layer,
                None,
                OpDims::MatMul { m: rows, k: cfg.ff, n: h },
                vec![f1, w_f2],
            );
            // C-OP-11: final layer-norm (residual add from ln1 fused).
            let add2 = g.push(
                &l("C-OP-11.add"),
                OpKind::Add,
                layer,
                None,
                OpDims::Elem { m: rows, n: h },
                vec![f2, ln1],
            );
            let ln2 = g.push(
                &l("C-OP-11.ln"),
                OpKind::LayerNorm,
                layer,
                None,
                OpDims::Elem { m: rows, n: h },
                vec![add2],
            );
            h_ready = ln2;
        }

        OpGraph { nodes: g.nodes, config: cfg.clone(), batch, seq }
    }

    /// Total scalar multiply-accumulates in all matmul ops (the dense
    /// compute the MAC lanes would execute at zero sparsity).
    pub fn total_macs(&self) -> usize {
        self.nodes
            .iter()
            .filter(|n| n.kind == OpKind::MatMul)
            .map(|n| n.dims.flops())
            .sum()
    }

    /// Ops of one kind.
    pub fn count(&self, kind: OpKind) -> usize {
        self.nodes.iter().filter(|n| n.kind == kind).count()
    }

    /// Validate the dependency structure: DAG, edges point backwards.
    pub fn validate(&self) -> Result<(), String> {
        for (i, n) in self.nodes.iter().enumerate() {
            if n.id != i {
                return Err(format!("node {i} has id {}", n.id));
            }
            for &d in &n.deps {
                if d >= i {
                    return Err(format!(
                        "node {} ({}) depends on later node {}",
                        i, n.label, d
                    ));
                }
            }
        }
        Ok(())
    }
}

struct Builder {
    nodes: Vec<OpNode>,
}

impl Builder {
    fn push(
        &mut self,
        label: &str,
        kind: OpKind,
        layer: usize,
        head: Option<usize>,
        dims: OpDims,
        deps: Vec<usize>,
    ) -> usize {
        let id = self.nodes.len();
        self.nodes.push(OpNode {
            id,
            label: label.to_string(),
            kind,
            layer,
            head,
            dims,
            deps,
        });
        id
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_graph() -> OpGraph {
        OpGraph::build(&TransformerConfig::bert_tiny(), 1, 128)
    }

    #[test]
    fn graph_is_valid_dag() {
        tiny_graph().validate().unwrap();
    }

    #[test]
    fn op_counts_match_table_i() {
        let g = tiny_graph();
        let cfg = &g.config;
        // per layer: 7 matmuls per head? No: C-OP-1..4,6,7 per head (6) +
        // 2 FFN matmuls per layer.
        assert_eq!(
            g.count(OpKind::MatMul),
            cfg.layers * (cfg.heads * 6 + 2)
        );
        assert_eq!(g.count(OpKind::Softmax), cfg.layers * cfg.heads);
        assert_eq!(g.count(OpKind::LayerNorm), cfg.layers * 2);
        // M-OP-0 + per layer {wqkv, wo, wf1, wf2}.
        assert_eq!(g.count(OpKind::MemLoad), 1 + cfg.layers * 4);
    }

    #[test]
    fn softmax_depends_on_qkt() {
        let g = tiny_graph();
        for n in &g.nodes {
            if n.kind == OpKind::Softmax {
                assert_eq!(n.deps.len(), 1);
                let dep = &g.nodes[n.deps[0]];
                assert!(dep.label.contains("C-OP-4"), "{}", dep.label);
            }
        }
    }

    #[test]
    fn total_macs_scale_with_batch() {
        let cfg = TransformerConfig::bert_tiny();
        let g1 = OpGraph::build(&cfg, 1, 128);
        let g4 = OpGraph::build(&cfg, 4, 128);
        assert_eq!(4 * g1.total_macs(), g4.total_macs());
    }

    #[test]
    fn layers_are_serialized_through_layernorm() {
        let g = tiny_graph();
        // every layer-1 Q projection must (transitively) depend on the
        // layer-0 C-OP-11 layer-norm; direct dep is enough to check here.
        let ln0 = g
            .nodes
            .iter()
            .find(|n| n.label == "l0.C-OP-11.ln")
            .unwrap()
            .id;
        let q1 = g
            .nodes
            .iter()
            .find(|n| n.label == "l1.h0.C-OP-1.q")
            .unwrap();
        assert!(q1.deps.contains(&ln0));
    }

    #[test]
    fn every_op_has_a_known_trace_class() {
        // The stable-identity contract between trace capture and the
        // simulator: no op of the Table I stream may fall into `Other`.
        let g = tiny_graph();
        for n in &g.nodes {
            assert_ne!(
                n.trace_class(),
                TraceClass::Other,
                "unclassified op '{}'",
                n.label
            );
        }
    }

    #[test]
    fn trace_class_counts_match_op_inventory() {
        let g = tiny_graph();
        let cfg = &g.config;
        let count = |c: TraceClass| {
            g.nodes.iter().filter(|n| n.trace_class() == c).count()
        };
        assert_eq!(count(TraceClass::Embedding), 1);
        assert_eq!(count(TraceClass::WqkvLoad), cfg.layers);
        assert_eq!(count(TraceClass::Qkv), cfg.layers * cfg.heads * 3);
        assert_eq!(count(TraceClass::AttnScore), cfg.layers * cfg.heads);
        assert_eq!(count(TraceClass::AttnContext), cfg.layers * cfg.heads);
        assert_eq!(count(TraceClass::AttnProj), cfg.layers * cfg.heads);
        assert_eq!(count(TraceClass::Softmax), cfg.layers * cfg.heads);
        // add + ln per residual block
        assert_eq!(count(TraceClass::AddNorm1), cfg.layers * 2);
        assert_eq!(count(TraceClass::AddNorm2), cfg.layers * 2);
        assert_eq!(count(TraceClass::Ffn1), cfg.layers);
        assert_eq!(count(TraceClass::Ffn2), cfg.layers);
    }

    #[test]
    fn per_head_ops_carry_head_index() {
        let g = tiny_graph();
        for n in &g.nodes {
            if n.label.contains(".h1.") {
                assert_eq!(n.head, Some(1));
            }
        }
    }
}
