//! Static weight pruning (paper Sec. V-A2): DynaTran's magnitude rule
//! applied *once* to model weights before inference ("WP"), and the
//! MP-like operating point (magnitude pruning to a target sparsity,
//! standing in for movement pruning — see DESIGN.md §Substitutions).

use crate::sim::dynatran;

/// Prune a flat weight buffer at a fixed threshold (WP).  Returns the
/// achieved weight sparsity.
pub fn weight_prune_threshold(weights: &mut [f32], tau: f32) -> f64 {
    dynatran::prune(weights, tau);
    dynatran::sparsity(weights)
}

/// Prune a flat weight buffer to a *target* sparsity by choosing the
/// magnitude quantile (the MP-like 50% operating point of Table IV).
/// Returns the threshold used.
pub fn weight_prune_to_sparsity(weights: &mut [f32], target_rho: f64) -> f32 {
    assert!((0.0..1.0).contains(&target_rho));
    if weights.is_empty() || target_rho == 0.0 {
        return 0.0;
    }
    let mut mags: Vec<f32> = weights.iter().map(|w| w.abs()).collect();
    mags.sort_unstable_by(|a, b| a.partial_cmp(b).unwrap());
    let idx = ((mags.len() as f64 * target_rho) as usize).min(mags.len() - 1);
    let tau = mags[idx];
    dynatran::prune(weights, tau);
    tau
}

/// Net sparsity over weights and activations combined, weighted by
/// element counts (the x-axis of Fig. 14).
pub fn net_sparsity(
    weight_rho: f64,
    weight_elems: usize,
    act_rho: f64,
    act_elems: usize,
) -> f64 {
    let total = (weight_elems + act_elems) as f64;
    if total == 0.0 {
        return 0.0;
    }
    (weight_rho * weight_elems as f64 + act_rho * act_elems as f64) / total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn prune_to_sparsity_hits_target() {
        let mut rng = Rng::new(5);
        let mut w = rng.normal_vec(50_000, 0.5);
        weight_prune_to_sparsity(&mut w, 0.5);
        let rho = dynatran::sparsity(&w);
        assert!((rho - 0.5).abs() < 0.01, "rho {rho}");
    }

    #[test]
    fn threshold_prune_reports_sparsity() {
        let mut w = vec![0.1, -0.9, 0.3, 0.0];
        let rho = weight_prune_threshold(&mut w, 0.2);
        assert_eq!(w, vec![0.0, -0.9, 0.3, 0.0]);
        assert_eq!(rho, 0.5);
    }

    #[test]
    fn net_sparsity_is_weighted_mean() {
        // activations dominate (Fig. 1), so net sparsity tracks act_rho:
        let net = net_sparsity(0.9, 100, 0.3, 900);
        assert!((net - 0.36).abs() < 1e-9);
    }

    #[test]
    fn net_sparsity_marginal_gain_from_wp() {
        // Sec. V-A2: high activation:weight ratio => WP adds little.
        let without_wp = net_sparsity(0.0, 100, 0.5, 900);
        let with_wp = net_sparsity(0.6, 100, 0.5, 900);
        assert!(with_wp - without_wp < 0.07);
    }
}
