//! Sparsity/accuracy profiling sweeps (Figs. 11 and 12 infrastructure).
//!
//! A sweep evaluates, per pruning hyper-parameter point (tau for
//! DynaTran, k for top-k), the resulting *net activation sparsity* and a
//! task metric (accuracy), producing the curves the DynaTran module's
//! threshold calculator stores (Sec. III-B5) and the comparisons of
//! Sec. V-A.

use crate::util::json::Json;

/// One point of a profiled curve.
#[derive(Clone, Copy, Debug)]
pub struct SweepPoint {
    /// The pruning hyper-parameter (tau, or keep-fraction for top-k).
    pub knob: f64,
    pub activation_sparsity: f64,
    pub accuracy: f64,
}

/// A labelled accuracy/sparsity curve.
#[derive(Clone, Debug)]
pub struct Curve {
    pub label: String,
    pub points: Vec<SweepPoint>,
}

impl Curve {
    pub fn new(label: impl Into<String>) -> Curve {
        Curve { label: label.into(), points: Vec::new() }
    }

    pub fn push(&mut self, knob: f64, activation_sparsity: f64, accuracy: f64) {
        self.points.push(SweepPoint { knob, activation_sparsity, accuracy });
    }

    /// Maximum accuracy along the curve (Fig. 12 annotations).
    pub fn max_accuracy(&self) -> f64 {
        self.points.iter().map(|p| p.accuracy).fold(f64::MIN, f64::max)
    }

    /// Maximum sparsity achieved with accuracy within `tol` of the
    /// curve's own maximum ("higher sparsity without much accuracy
    /// loss", Sec. V-A1).
    pub fn max_sparsity_within(&self, tol: f64) -> f64 {
        let best = self.max_accuracy();
        self.points
            .iter()
            .filter(|p| p.accuracy >= best - tol)
            .map(|p| p.activation_sparsity)
            .fold(0.0, f64::max)
    }

    /// Highest sparsity at which accuracy still reaches `floor` —
    /// the "same accuracy, 1.17x–1.2x higher sparsity" comparison.
    pub fn sparsity_at_accuracy(&self, floor: f64) -> Option<f64> {
        self.points
            .iter()
            .filter(|p| p.accuracy >= floor)
            .map(|p| p.activation_sparsity)
            .fold(None, |acc, s| Some(acc.map_or(s, |a: f64| a.max(s))))
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("label", Json::str(self.label.clone())),
            (
                "points",
                Json::arr(self.points.iter().map(|p| {
                    Json::obj(vec![
                        ("knob", Json::num(p.knob)),
                        ("sparsity", Json::num(p.activation_sparsity)),
                        ("accuracy", Json::num(p.accuracy)),
                    ])
                })),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn curve() -> Curve {
        let mut c = Curve::new("dynatran");
        // typical shape: slight rise, plateau, cliff
        c.push(0.00, 0.10, 0.880);
        c.push(0.02, 0.30, 0.885);
        c.push(0.04, 0.45, 0.884);
        c.push(0.06, 0.55, 0.870);
        c.push(0.08, 0.65, 0.700);
        c
    }

    #[test]
    fn max_accuracy_finds_bump() {
        assert_eq!(curve().max_accuracy(), 0.885);
    }

    #[test]
    fn max_sparsity_within_tolerance() {
        let c = curve();
        assert_eq!(c.max_sparsity_within(0.002), 0.45);
        assert_eq!(c.max_sparsity_within(0.02), 0.55);
    }

    #[test]
    fn sparsity_at_accuracy_floor() {
        let c = curve();
        assert_eq!(c.sparsity_at_accuracy(0.86), Some(0.55));
        assert_eq!(c.sparsity_at_accuracy(0.95), None);
    }

    #[test]
    fn json_roundtrip_fields() {
        let j = curve().to_json();
        assert_eq!(j.get("label").unwrap().as_str(), Some("dynatran"));
        assert_eq!(j.get("points").unwrap().as_arr().unwrap().len(), 5);
    }
}
