//! Host-side pruning algorithms over f32 tensors — the software twins of
//! the hardware DynaTran module and the SpAtten-style top-k baseline —
//! plus profiling utilities for the Figs. 11–14 curves and the Fig. 13
//! compute-cost comparison.
//!
//! The functional model inference (accuracy axes of those figures) runs
//! through the PJRT runtime; this module supplies the *pruning-strategy*
//! side: threshold sweeps, sparsity accounting, static weight pruning
//! ("WP" and the MP-like 50% operating point), and CPU-throughput
//! measurement of DynaTran vs top-k.

pub mod profile;
pub mod wp;

pub use crate::sim::dynatran::{pruned, sparsity, topk_prune_rows, TransferFunction};

/// DynaTran one-pass pruning throughput payload: prune a matrix in place.
/// O(N) single comparison per element — contrast with top-k's per-row
/// sort in [`topk_prune_rows`].  Both are exercised by
/// `benches/fig13_prune_throughput.rs`.
///
/// §Perf: written branchless (select + count as a data-parallel sum) so
/// LLVM auto-vectorizes; the naive branchy loop measured 0.7 GB/s at 50%
/// sparsity (misprediction-bound), this form reaches multi-GB/s — the
/// software mirror of the hardware module's comparator array.
pub fn dynatran_prune_inplace(values: &mut [f32], tau: f32) -> usize {
    let mut pruned_count = 0usize;
    for v in values.iter_mut() {
        let keep = v.abs() >= tau;
        *v = if keep { *v } else { 0.0 };
        pruned_count += !keep as usize;
    }
    pruned_count
}

use crate::runtime::tensor::{GEMM_KC, GEMM_MR};

/// Per-tile zero bitmap over a row-major `rows x cols` activation,
/// using the host GEMM's broadcast-operand tile geometry
/// (`GEMM_MR x GEMM_KC`): the mask → tile-bitmap handoff between
/// DynaTran pruning and the blocked microkernel.  `zero[rt * depth_blocks
/// + pc]` is true iff row tile `rt` of depth block `pc` is entirely zero
/// — exactly the tiles `runtime::tensor::matmul_ex` will skip when this
/// matrix is its left operand (pinned by a cross-check in
/// `tests/gemm_oracle.rs`).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TileMap {
    /// Row tiles (`ceil(rows / GEMM_MR)`).
    pub row_tiles: usize,
    /// Depth blocks (`ceil(cols / GEMM_KC)`).
    pub depth_blocks: usize,
    /// `row_tiles * depth_blocks` flags, row-tile-major.
    pub zero: Vec<bool>,
}

impl TileMap {
    /// Scan a pruned activation into its tile bitmap.
    pub fn from_matrix(values: &[f32], rows: usize, cols: usize) -> TileMap {
        assert_eq!(values.len(), rows * cols, "TileMap: shape");
        let row_tiles = (rows + GEMM_MR - 1) / GEMM_MR;
        let depth_blocks = (cols + GEMM_KC - 1) / GEMM_KC;
        let mut zero = vec![true; row_tiles * depth_blocks];
        for r in 0..rows {
            let row = &values[r * cols..(r + 1) * cols];
            let rt = r / GEMM_MR;
            for pc in 0..depth_blocks {
                if zero[rt * depth_blocks + pc] {
                    let c0 = pc * GEMM_KC;
                    let cl = (cols - c0).min(GEMM_KC);
                    if row[c0..c0 + cl].iter().any(|&v| v != 0.0) {
                        zero[rt * depth_blocks + pc] = false;
                    }
                }
            }
        }
        TileMap { row_tiles, depth_blocks, zero }
    }

    /// Total tiles in the map.
    pub fn tiles(&self) -> usize {
        self.zero.len()
    }

    /// Fully-zero (skippable) tiles.
    pub fn zero_tiles(&self) -> usize {
        self.zero.iter().filter(|&&z| z).count()
    }

    /// Share of tiles the microkernel must still compute (1.0 for an
    /// empty map).
    pub fn effectual_tile_fraction(&self) -> f64 {
        if self.zero.is_empty() {
            1.0
        } else {
            1.0 - self.zero_tiles() as f64 / self.tiles() as f64
        }
    }
}

/// Fused DynaTran prune + tile-map build: prune `values` in place at
/// threshold `tau` (same semantics as [`dynatran_prune_inplace`]) and
/// return the pruned-element count alongside the [`TileMap`] the blocked
/// GEMM will observe on this matrix.  One pass over the data instead of
/// prune-then-rescan.
pub fn dynatran_prune_tiled(
    values: &mut [f32],
    tau: f32,
    rows: usize,
    cols: usize,
) -> (usize, TileMap) {
    assert_eq!(values.len(), rows * cols, "dynatran_prune_tiled: shape");
    let row_tiles = (rows + GEMM_MR - 1) / GEMM_MR;
    let depth_blocks = (cols + GEMM_KC - 1) / GEMM_KC;
    let mut zero = vec![true; row_tiles * depth_blocks];
    let mut pruned_count = 0usize;
    for r in 0..rows {
        let rt = r / GEMM_MR;
        let row = &mut values[r * cols..(r + 1) * cols];
        for pc in 0..depth_blocks {
            let c0 = pc * GEMM_KC;
            let cl = (cols - c0).min(GEMM_KC);
            let mut any = false;
            for v in row[c0..c0 + cl].iter_mut() {
                let keep = v.abs() >= tau;
                *v = if keep { *v } else { 0.0 };
                pruned_count += !keep as usize;
                any |= *v != 0.0;
            }
            if any {
                zero[rt * depth_blocks + pc] = false;
            }
        }
    }
    (pruned_count, TileMap { row_tiles, depth_blocks, zero })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn inplace_matches_functional() {
        let data = vec![0.3f32, -0.05, 0.8, 0.0, -0.4];
        let mut a = data.clone();
        let n = dynatran_prune_inplace(&mut a, 0.25);
        let (b, mask) = pruned(&data, 0.25);
        assert_eq!(a, b);
        assert_eq!(n, mask.iter().filter(|&&m| m).count());
    }

    #[test]
    fn fused_prune_matches_inplace_then_scan() {
        let mut rng = crate::util::rng::Rng::new(7);
        let (rows, cols) = (11, 300); // ragged in both tile dimensions
        let data = rng.normal_vec(rows * cols, 0.05);
        let mut a = data.clone();
        let mut b = data.clone();
        let na = dynatran_prune_inplace(&mut a, 0.04);
        let (nb, map) = dynatran_prune_tiled(&mut b, 0.04, rows, cols);
        assert_eq!(a, b, "fused prune must produce the identical matrix");
        assert_eq!(na, nb);
        assert_eq!(map, TileMap::from_matrix(&a, rows, cols));
        assert_eq!(map.row_tiles, 3);
        assert_eq!(map.depth_blocks, 3);
        assert_eq!(map.tiles(), 9);
    }

    #[test]
    fn tile_map_flags_structured_zero_rows() {
        // rows 0..4 zeroed => the whole first row tile is skippable
        let (rows, cols) = (8, 130);
        let mut m = vec![1.0f32; rows * cols];
        for v in m[..4 * cols].iter_mut() {
            *v = 0.0;
        }
        let map = TileMap::from_matrix(&m, rows, cols);
        assert_eq!(map.row_tiles, 2);
        assert_eq!(map.depth_blocks, 2);
        assert_eq!(map.zero_tiles(), 2);
        assert_eq!(map.zero, vec![true, true, false, false]);
        assert!((map.effectual_tile_fraction() - 0.5).abs() < 1e-12);
    }
}
