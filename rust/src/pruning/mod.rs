//! Host-side pruning algorithms over f32 tensors — the software twins of
//! the hardware DynaTran module and the SpAtten-style top-k baseline —
//! plus profiling utilities for the Figs. 11–14 curves and the Fig. 13
//! compute-cost comparison.
//!
//! The functional model inference (accuracy axes of those figures) runs
//! through the PJRT runtime; this module supplies the *pruning-strategy*
//! side: threshold sweeps, sparsity accounting, static weight pruning
//! ("WP" and the MP-like 50% operating point), and CPU-throughput
//! measurement of DynaTran vs top-k.

pub mod profile;
pub mod wp;

pub use crate::sim::dynatran::{pruned, sparsity, topk_prune_rows, TransferFunction};

/// DynaTran one-pass pruning throughput payload: prune a matrix in place.
/// O(N) single comparison per element — contrast with top-k's per-row
/// sort in [`topk_prune_rows`].  Both are exercised by
/// `benches/fig13_prune_throughput.rs`.
///
/// §Perf: written branchless (select + count as a data-parallel sum) so
/// LLVM auto-vectorizes; the naive branchy loop measured 0.7 GB/s at 50%
/// sparsity (misprediction-bound), this form reaches multi-GB/s — the
/// software mirror of the hardware module's comparator array.
pub fn dynatran_prune_inplace(values: &mut [f32], tau: f32) -> usize {
    let mut pruned_count = 0usize;
    for v in values.iter_mut() {
        let keep = v.abs() >= tau;
        *v = if keep { *v } else { 0.0 };
        pruned_count += !keep as usize;
    }
    pruned_count
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn inplace_matches_functional() {
        let data = vec![0.3f32, -0.05, 0.8, 0.0, -0.4];
        let mut a = data.clone();
        let n = dynatran_prune_inplace(&mut a, 0.25);
        let (b, mask) = pruned(&data, 0.25);
        assert_eq!(a, b);
        assert_eq!(n, mask.iter().filter(|&&m| m).count());
    }
}
