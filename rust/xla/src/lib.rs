//! In-tree stand-in for the `xla` PJRT bindings (xla-rs API surface).
//!
//! The AccelTran runtime layer (`acceltran::runtime`) is written against
//! the xla-rs flavour of the PJRT C API: [`Literal`] host tensors,
//! [`PjRtClient`] → [`PjRtLoadedExecutable`] → [`PjRtBuffer`], and HLO
//! text ingestion via [`HloModuleProto`] / [`XlaComputation`].  This
//! build image does not ship `libxla_extension`, so this crate provides
//! the same surface in two tiers (DESIGN.md §Substitutions):
//!
//! * **Functional** — [`Literal`] is a real host tensor (typed element
//!   storage + shape), so parameter stores, batching, golden-file I/O,
//!   and every compile-time consumer work unchanged.
//! * **Stubbed** — [`PjRtClient::compile`] returns an error: no HLO can
//!   execute without the native backend.  The `acceltran` runtime only
//!   selects its PJRT backend when artifacts are present (its pure-Rust
//!   reference executor is the default otherwise), so tier-1 builds and
//!   tests stay hermetic and green.
//!
//! Swapping in the real bindings is a one-line change in
//! `rust/Cargo.toml` (point the `xla` dependency at the real crate);
//! nothing in `acceltran` itself changes.

use std::fmt;

/// `true` in this stub build; the real bindings do not define it, which
/// makes accidental use of stub-only behaviour a compile error after a
/// swap rather than a silent fallback.
pub const IS_STUB: bool = true;

/// Error type mirroring xla-rs: carries a message, formats like the
/// native error strings the runtime wraps with `anyhow!("...: {e:?}")`.
pub struct XlaError {
    pub msg: String,
}

impl XlaError {
    fn new(msg: impl Into<String>) -> XlaError {
        XlaError { msg: msg.into() }
    }

    fn stub(what: &str) -> XlaError {
        XlaError::new(format!(
            "{what}: xla stub (no native PJRT backend in this build; \
             see DESIGN.md §Substitutions)"
        ))
    }
}

impl fmt::Debug for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "XlaError({})", self.msg)
    }
}

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for XlaError {}

/// Typed element storage of a [`Literal`].
#[derive(Clone, Debug, PartialEq)]
pub enum LiteralData {
    F32(Vec<f32>),
    F64(Vec<f64>),
    I32(Vec<i32>),
    I64(Vec<i64>),
    /// Tuple literals, as produced by `return_tuple=True` lowerings.
    Tuple(Vec<Literal>),
}

impl LiteralData {
    fn element_count(&self) -> usize {
        match self {
            LiteralData::F32(v) => v.len(),
            LiteralData::F64(v) => v.len(),
            LiteralData::I32(v) => v.len(),
            LiteralData::I64(v) => v.len(),
            LiteralData::Tuple(v) => v.len(),
        }
    }

    fn dtype_name(&self) -> &'static str {
        match self {
            LiteralData::F32(_) => "f32",
            LiteralData::F64(_) => "f64",
            LiteralData::I32(_) => "i32",
            LiteralData::I64(_) => "i64",
            LiteralData::Tuple(_) => "tuple",
        }
    }
}

/// Element types a [`Literal`] can hold.  Sealed by construction: only
/// the types below implement it.
pub trait NativeType: Copy + Sized {
    fn wrap(data: Vec<Self>) -> LiteralData;
    fn unwrap(data: &LiteralData) -> Option<Vec<Self>>;
}

impl NativeType for f32 {
    fn wrap(data: Vec<f32>) -> LiteralData {
        LiteralData::F32(data)
    }
    fn unwrap(data: &LiteralData) -> Option<Vec<f32>> {
        match data {
            LiteralData::F32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

impl NativeType for f64 {
    fn wrap(data: Vec<f64>) -> LiteralData {
        LiteralData::F64(data)
    }
    fn unwrap(data: &LiteralData) -> Option<Vec<f64>> {
        match data {
            LiteralData::F64(v) => Some(v.clone()),
            _ => None,
        }
    }
}

impl NativeType for i32 {
    fn wrap(data: Vec<i32>) -> LiteralData {
        LiteralData::I32(data)
    }
    fn unwrap(data: &LiteralData) -> Option<Vec<i32>> {
        match data {
            LiteralData::I32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

impl NativeType for i64 {
    fn wrap(data: Vec<i64>) -> LiteralData {
        LiteralData::I64(data)
    }
    fn unwrap(data: &LiteralData) -> Option<Vec<i64>> {
        match data {
            LiteralData::I64(v) => Some(v.clone()),
            _ => None,
        }
    }
}

/// A host tensor: typed flat element storage plus a shape.  Fully
/// functional (not stubbed) — the coordinator's parameter plumbing and
/// the golden-file tests rely on real round-trips.
#[derive(Clone, Debug, PartialEq)]
pub struct Literal {
    data: LiteralData,
    dims: Vec<i64>,
}

impl Literal {
    /// Rank-1 literal from a slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal {
            dims: vec![data.len() as i64],
            data: T::wrap(data.to_vec()),
        }
    }

    /// Rank-0 (scalar) literal.
    pub fn scalar<T: NativeType>(value: T) -> Literal {
        Literal { dims: Vec::new(), data: T::wrap(vec![value]) }
    }

    /// Tuple literal (what `return_tuple=True` computations produce).
    pub fn tuple(elements: Vec<Literal>) -> Literal {
        Literal {
            dims: vec![elements.len() as i64],
            data: LiteralData::Tuple(elements),
        }
    }

    /// Shape of the literal.
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    /// Total element count.
    pub fn element_count(&self) -> usize {
        self.data.element_count()
    }

    /// Same storage under a new shape; errors when the element counts
    /// disagree (matching the native reshape contract).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal, XlaError> {
        if matches!(self.data, LiteralData::Tuple(_)) {
            return Err(XlaError::new("reshape: cannot reshape a tuple literal"));
        }
        let want: i64 = dims.iter().product();
        if want < 0 || want as usize != self.element_count() {
            return Err(XlaError::new(format!(
                "reshape: {} elements into shape {dims:?}",
                self.element_count()
            )));
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
    }

    /// Copy the elements out as `Vec<T>`; errors on a dtype mismatch.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>, XlaError> {
        T::unwrap(&self.data).ok_or_else(|| {
            XlaError::new(format!(
                "to_vec: literal holds {} data",
                self.data.dtype_name()
            ))
        })
    }

    /// Split a tuple literal into its elements; errors on non-tuples.
    pub fn to_tuple(&self) -> Result<Vec<Literal>, XlaError> {
        match &self.data {
            LiteralData::Tuple(elements) => Ok(elements.clone()),
            _ => Err(XlaError::new(format!(
                "to_tuple: literal holds {} data, not a tuple",
                self.data.dtype_name()
            ))),
        }
    }
}

/// Parsed HLO module.  The stub validates that the file exists and is
/// readable (so missing-artifact errors stay accurate) but does not
/// parse HLO text.
#[derive(Clone, Debug)]
pub struct HloModuleProto {
    pub source_path: String,
}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto, XlaError> {
        match std::fs::metadata(path) {
            Ok(_) => Ok(HloModuleProto { source_path: path.to_string() }),
            Err(e) => Err(XlaError::new(format!("reading {path}: {e}"))),
        }
    }
}

/// A computation wrapping an HLO module, ready for compilation.
#[derive(Clone, Debug)]
pub struct XlaComputation {
    pub proto: HloModuleProto,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { proto: proto.clone() }
    }
}

/// PJRT client handle.  Construction succeeds (manifest-only flows and
/// server plumbing need a client value); compilation is where the stub
/// reports the missing native backend.
#[derive(Debug)]
pub struct PjRtClient {
    platform: &'static str,
}

impl PjRtClient {
    /// The CPU PJRT client.
    pub fn cpu() -> Result<PjRtClient, XlaError> {
        Ok(PjRtClient { platform: "cpu-stub" })
    }

    pub fn platform_name(&self) -> &'static str {
        self.platform
    }

    /// Always errors in the stub: executing HLO needs the native
    /// `libxla_extension` backend.
    pub fn compile(
        &self,
        computation: &XlaComputation,
    ) -> Result<PjRtLoadedExecutable, XlaError> {
        Err(XlaError::stub(&format!(
            "compile({})",
            computation.proto.source_path
        )))
    }
}

/// A compiled executable.  Unconstructable through the stub client, but
/// the type (and its `execute` shape) must exist for callers to
/// typecheck against the real API.
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    /// Mirrors xla-rs: one result row per device, one buffer per output.
    pub fn execute<T: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[T],
    ) -> Result<Vec<Vec<PjRtBuffer>>, XlaError> {
        Err(XlaError::stub("execute"))
    }
}

/// A device buffer holding one executable output.
#[derive(Debug)]
pub struct PjRtBuffer {
    literal: Literal,
}

impl PjRtBuffer {
    /// Copy the buffer back to a host [`Literal`].
    pub fn to_literal_sync(&self) -> Result<Literal, XlaError> {
        Ok(self.literal.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vec1_roundtrips_f32_and_i32() {
        let f = Literal::vec1(&[1.0f32, -2.5, 0.0]);
        assert_eq!(f.dims(), &[3]);
        assert_eq!(f.to_vec::<f32>().unwrap(), vec![1.0, -2.5, 0.0]);
        assert!(f.to_vec::<i32>().is_err());

        let i = Literal::vec1(&[7i32, 8]);
        assert_eq!(i.to_vec::<i32>().unwrap(), vec![7, 8]);
    }

    #[test]
    fn scalar_is_rank_zero() {
        let s = Literal::scalar(0.05f32);
        assert_eq!(s.dims(), &[] as &[i64]);
        assert_eq!(s.element_count(), 1);
    }

    #[test]
    fn reshape_checks_element_count() {
        let l = Literal::vec1(&(0..12).collect::<Vec<i32>>());
        let r = l.reshape(&[3, 4]).unwrap();
        assert_eq!(r.dims(), &[3, 4]);
        assert_eq!(r.to_vec::<i32>().unwrap().len(), 12);
        assert!(l.reshape(&[5, 5]).is_err());
    }

    #[test]
    fn tuple_roundtrip() {
        let t = Literal::tuple(vec![
            Literal::vec1(&[1.0f32]),
            Literal::scalar(2i32),
        ]);
        let parts = t.to_tuple().unwrap();
        assert_eq!(parts.len(), 2);
        assert_eq!(parts[1].to_vec::<i32>().unwrap(), vec![2]);
        assert!(Literal::scalar(1i32).to_tuple().is_err());
    }

    #[test]
    fn client_constructs_but_compile_is_stubbed() {
        let client = PjRtClient::cpu().unwrap();
        assert_eq!(client.platform_name(), "cpu-stub");
        let dir = std::env::temp_dir();
        let path = dir.join(format!("xla_stub_test_{}.hlo.txt", std::process::id()));
        std::fs::write(&path, "HloModule m").unwrap();
        let proto = HloModuleProto::from_text_file(path.to_str().unwrap()).unwrap();
        let comp = XlaComputation::from_proto(&proto);
        let err = client.compile(&comp).unwrap_err();
        assert!(err.msg.contains("stub"), "{err:?}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn missing_hlo_file_is_a_clear_error() {
        let err = HloModuleProto::from_text_file("/nonexistent/x.hlo.txt")
            .unwrap_err();
        assert!(err.msg.contains("/nonexistent/x.hlo.txt"));
    }
}
