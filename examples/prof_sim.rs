//! §Perf profiling driver: 200 back-to-back Edge simulations for
//! `perf record` (see EXPERIMENTS.md §Perf).  Not a demo — use
//! `examples/quickstart.rs` for that.
use acceltran::model::{OpGraph, TransformerConfig};
use acceltran::sim::engine::{Engine, SparsityProfile};
use acceltran::sim::scheduler::Policy;
use acceltran::sim::AcceleratorConfig;
fn main() {
    let model = TransformerConfig::bert_tiny();
    let cfg = AcceleratorConfig::edge();
    let graph = OpGraph::build(&model, cfg.batch, 128);
    let mut acc = 0u64;
    for _ in 0..200 {
        acc += Engine::new(cfg.clone(), &graph, Policy::Staggered,
                           SparsityProfile::paper_default()).run().total_cycles;
    }
    println!("{acc}");
}
