//! End-to-end validation driver (EXPERIMENTS.md §End-to-end): train the
//! BERT-Tiny-shaped encoder on the synthetic sentiment corpus entirely
//! in Rust (native backprop + AdamW on the reference backend; the AOT
//! `train_step_b32` artifact under PJRT — Python never runs), log the
//! loss curve, then regenerate the DynaTran accuracy-vs-sparsity
//! trade-off on the *trained* model (the Fig. 11/12 experiment at this
//! model scale).
//!
//! Run with: `cargo run --release --example train_sentiment -- [steps]`

use acceltran::coordinator::{self};
use acceltran::nlp::sentiment::SentimentTask;
use acceltran::runtime::{ParamStore, Runtime};
use acceltran::util::table::Table;
use anyhow::Result;

fn main() -> Result<()> {
    let steps: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(300);
    let mut rt = Runtime::load_default()?;
    let vocab = rt.manifest.vocab;
    let seq = rt.manifest.seq;
    let task = SentimentTask::new(vocab, seq, 7);
    let train_ds = task.dataset(4096, 1);
    let val_ds = task.dataset(768, 2);
    println!(
        "synthetic sentiment: 4096 train / 768 val, lexicon oracle accuracy {:.3}",
        task.lexicon_accuracy(&val_ds)
    );

    let mut store = ParamStore::init(&rt.manifest, 0);
    println!(
        "training {} ({} params) for {steps} AdamW steps (b=32, lr=1e-3) \
         on the '{}' backend...",
        rt.manifest.model_name,
        rt.manifest.param_count,
        rt.backend_name()
    );
    let t0 = std::time::Instant::now();
    let log = coordinator::train(
        &mut rt, &mut store, &train_ds, Some(&val_ds), steps, 1e-3, 50, true,
    )?;
    let train_time = t0.elapsed();
    let (head, tail) = log.head_tail_means(10);
    println!(
        "loss curve: {head:.4} -> {tail:.4} over {steps} steps in {train_time:?} \
         ({:.1} steps/s)",
        steps as f64 / train_time.as_secs_f64()
    );

    // accuracy-vs-sparsity trade-off on the trained model
    let taus = [0.0f32, 0.01, 0.02, 0.03, 0.04, 0.06, 0.08, 0.10];
    let curve =
        coordinator::sweep_dynatran(&mut rt, &store.params, &val_ds, &taus, 512)?;
    println!("\nDynaTran sweep on the trained model (Fig. 11(a)/12 shape):");
    let mut t = Table::new(["tau", "activation sparsity", "accuracy"]);
    for p in &curve.points {
        t.row([
            format!("{:.2}", p.knob),
            format!("{:.3}", p.activation_sparsity),
            format!("{:.4}", p.accuracy),
        ]);
    }
    t.print();
    println!(
        "max accuracy {:.4}; max sparsity within 1% of it: {:.3}",
        curve.max_accuracy(),
        curve.max_sparsity_within(0.01)
    );
    store.save("reports/train_sentiment_params.bin").ok();
    Ok(())
}
