//! HTTP serving scenario / load generator for the `serve::net`
//! front-end, in two modes:
//!
//! * **Hermetic** (default): start the HTTP server in-process on a
//!   loopback port, fire concurrent keep-alive clients at it, scrape
//!   `/stats` mid-flight, drain, and print both sides' accounting.
//!
//!   `cargo run --release --example http_serve -- [--requests 512]`
//!
//! * **External** (`--connect ADDR`): drive an already-running
//!   `acceltran serve --listen ...` — the CI smoke job uses this.  The
//!   model shape is discovered from `/healthz`, so the generator works
//!   against any served model.
//!
//!   `cargo run --release --example http_serve -- --connect 127.0.0.1:8080`
//!
//! `--mixed-len` draws each request's native length ~ U[8, seq] instead
//! of always seq, exercising the server's length-bucketed continuous
//! batching (the CI smoke job asserts on the resulting
//! `padded_token_fraction` and `rejected_429` observables).
//!
//! `--span-frac F` sends that fraction of requests to `/v1/span`
//! instead of `/v1/classify` — a mixed two-task workload against a
//! multi-model server.  Shapes come per task from the `/healthz`
//! `models` array; the summary carries per-task `ok` counts (the CI
//! smoke job asserts both are positive).  In hermetic mode a span
//! model is registered alongside the classify one.
//!
//! Either way a JSON summary lands at `--out` (default
//! `reports/http_serve.json`).

use acceltran::coordinator::{ModelEntry, TaskKind};
use acceltran::runtime::{ParamStore, Runtime};
use acceltran::serve::net::{HttpClient, NetConfig, NetServer};
use acceltran::util::cli::Args;
use acceltran::util::json::Json;
use acceltran::util::rng::Rng;
use anyhow::{anyhow, Context, Result};
use std::time::Instant;

/// Model shape a generator needs to build valid requests.
#[derive(Clone)]
struct Shape {
    seq: usize,
    vocab: usize,
}

/// Per-task shapes discovered from `/healthz`: the first registered
/// model of each task (mirroring the server's default routing).
struct TaskShapes {
    classify: Option<Shape>,
    span: Option<Shape>,
}

fn shapes_from_healthz(addr: &str) -> Result<TaskShapes> {
    let mut c = HttpClient::connect(addr)
        .with_context(|| format!("connecting to {addr}"))?;
    let (status, body) = c.get("/healthz").context("GET /healthz")?;
    if status != 200 {
        return Err(anyhow!("/healthz returned {status}"));
    }
    let mut shapes = TaskShapes { classify: None, span: None };
    if let Some(models) = body.get("models").and_then(|m| m.as_arr()) {
        for m in models {
            let shape = Shape {
                seq: m
                    .get("seq")
                    .and_then(|v| v.as_usize())
                    .ok_or_else(|| anyhow!("/healthz model missing seq"))?,
                vocab: m
                    .get("vocab")
                    .and_then(|v| v.as_usize())
                    .ok_or_else(|| anyhow!("/healthz model missing vocab"))?,
            };
            match m.get("task").and_then(|v| v.as_str()) {
                Some("classify") if shapes.classify.is_none() => {
                    shapes.classify = Some(shape);
                }
                Some("span") if shapes.span.is_none() => {
                    shapes.span = Some(shape);
                }
                _ => {}
            }
        }
    }
    if shapes.classify.is_none() {
        // pre-multi-model servers: the top-level "model" object is the
        // (classify) model
        let seq = body
            .path(&["model", "seq"])
            .and_then(|v| v.as_usize())
            .ok_or_else(|| anyhow!("/healthz missing model.seq"))?;
        let vocab = body
            .path(&["model", "vocab"])
            .and_then(|v| v.as_usize())
            .ok_or_else(|| anyhow!("/healthz missing model.vocab"))?;
        shapes.classify = Some(Shape { seq, vocab });
    }
    Ok(shapes)
}

fn classify_body(
    rng: &mut Rng,
    shape: &Shape,
    tau: f32,
    mixed_len: bool,
) -> Json {
    // mixed-length mode exercises continuous batching: native lengths
    // ~ U[lo, seq] land in different seq buckets server-side
    let len = if mixed_len {
        let lo = 8usize.min(shape.seq);
        lo + rng.below((shape.seq - lo + 1) as u64) as usize
    } else {
        shape.seq
    };
    let ids: Vec<Json> = (0..len)
        .map(|_| Json::num(rng.below(shape.vocab as u64) as f64))
        .collect();
    Json::obj(vec![
        ("ids", Json::arr(ids)),
        ("tau", Json::num(tau as f64)),
    ])
}

/// Per-task `(ok, failed)` tallies from one or more clients.
#[derive(Default, Clone, Copy)]
struct TaskTally {
    ok: u64,
    failed: u64,
}

/// One client connection's worth of load; returns per-task tallies and
/// per-request latencies in us.  Each request rolls `span_frac` to pick
/// its endpoint (span requests need a span shape, enforced by the
/// caller).
fn run_client(
    addr: String,
    classify: Shape,
    span: Option<Shape>,
    n: usize,
    seed: u64,
    tau: f32,
    mixed_len: bool,
    span_frac: f64,
) -> Result<(TaskTally, TaskTally, Vec<u64>)> {
    let mut rng = Rng::new(seed);
    let mut client = HttpClient::connect(&addr)?;
    let mut clf = TaskTally::default();
    let mut spn = TaskTally::default();
    let mut lat = Vec::with_capacity(n);
    let span_permille = (span_frac.clamp(0.0, 1.0) * 1000.0) as u64;
    for _ in 0..n {
        let is_span =
            span.is_some() && rng.below(1000) < span_permille;
        let (path, shape) = if is_span {
            ("/v1/span", span.as_ref().unwrap())
        } else {
            ("/v1/classify", &classify)
        };
        let body = classify_body(&mut rng, shape, tau, mixed_len);
        let t0 = Instant::now();
        let (status, resp) = client.post_json(path, &body)?;
        lat.push(t0.elapsed().as_micros() as u64);
        let has_logits = resp
            .get("logits")
            .and_then(|l| l.as_arr())
            .map(|a| !a.is_empty())
            .unwrap_or(false);
        // span answers additionally carry the decoded argmax positions
        let well_formed = has_logits
            && (!is_span
                || (resp.get("start").is_some() && resp.get("end").is_some()));
        let tally = if is_span { &mut spn } else { &mut clf };
        if status == 200 && well_formed {
            tally.ok += 1;
        } else {
            tally.failed += 1;
        }
    }
    Ok((clf, spn, lat))
}

fn percentile(sorted: &[u64], p: f64) -> u64 {
    if sorted.is_empty() {
        return 0;
    }
    let idx = ((sorted.len() - 1) as f64 * p / 100.0).round() as usize;
    sorted[idx]
}

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1), false);
    let total = args.get_usize("requests", 512);
    let conns = args.get_usize("conns", 4).max(1);
    let tau = args.get_f64("tau", 0.04) as f32;
    let mixed_len = args.has("mixed-len");
    let span_frac = args.get_f64("span-frac", 0.0);
    let out = args.get_or("out", "reports/http_serve.json").to_string();

    // external mode drives a server someone else owns; hermetic mode
    // owns one in-process and drains it at the end
    let (addr, server) = match args.get("connect") {
        Some(a) => (a.to_string(), None),
        None => {
            let rt = Runtime::load_default()?;
            let params = ParamStore::init(&rt.manifest, 0).params;
            let cfg = NetConfig {
                pools: args.get_usize("pools", 2),
                ..NetConfig::default()
            };
            let server = if span_frac > 0.0 {
                // mixed workload: register a span model (its own
                // checkpoint over the same encoder shape) alongside
                // the classify one
                let span_params = ParamStore::init(&rt.manifest, 1).params;
                let entries = vec![
                    ModelEntry {
                        name: "classify".into(),
                        task: TaskKind::Classify,
                        runtime: rt.fork()?,
                        params,
                        sim: None,
                    },
                    ModelEntry {
                        name: "span".into(),
                        task: TaskKind::Span,
                        runtime: rt.fork()?,
                        params: span_params,
                        sim: None,
                    },
                ];
                NetServer::start_multi(entries, &cfg)?
            } else {
                NetServer::start(&rt, &params, &cfg)?
            };
            println!(
                "hermetic server on http://{} ({} pools, '{}' backend)",
                server.addr(),
                cfg.pools,
                rt.backend_name()
            );
            (server.addr().to_string(), Some(server))
        }
    };

    let shapes = shapes_from_healthz(&addr)?;
    let shape = shapes
        .classify
        .clone()
        .ok_or_else(|| anyhow!("no classify model served"))?;
    if span_frac > 0.0 && shapes.span.is_none() {
        return Err(anyhow!(
            "--span-frac {span_frac} but the server registers no span model"
        ));
    }
    println!(
        "target {addr}: seq={} vocab={} — {total} requests over {conns} \
         connection(s), tau={tau}{}{}",
        shape.seq,
        shape.vocab,
        if mixed_len { ", mixed-length" } else { "" },
        if span_frac > 0.0 {
            format!(", span fraction {span_frac}")
        } else {
            String::new()
        }
    );

    let per_conn = total.div_ceil(conns);
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for c in 0..conns {
        let addr = addr.clone();
        let shape = shape.clone();
        let span_shape = shapes.span.clone();
        let n = per_conn.min(total - (per_conn * c).min(total));
        handles.push(std::thread::spawn(move || {
            run_client(
                addr,
                shape,
                span_shape,
                n,
                0x9e00 + c as u64,
                tau,
                mixed_len,
                span_frac,
            )
        }));
    }
    // scrape /stats while the load is in flight — this is the endpoint
    // an operator would watch
    let mid_stats = HttpClient::connect(&addr)
        .and_then(|mut c| c.get("/stats"))
        .ok();
    let mut clf = TaskTally::default();
    let mut spn = TaskTally::default();
    let mut lat: Vec<u64> = Vec::new();
    for h in handles {
        let (c, s, l) = h.join().map_err(|_| anyhow!("client panicked"))??;
        clf.ok += c.ok;
        clf.failed += c.failed;
        spn.ok += s.ok;
        spn.failed += s.failed;
        lat.extend(l);
    }
    let ok = clf.ok + spn.ok;
    let failed = clf.failed + spn.failed;
    let wall = t0.elapsed();
    lat.sort_unstable();
    let rps = ok as f64 / wall.as_secs_f64();
    println!(
        "{ok} ok / {failed} failed in {:.2}s — {rps:.1} req/s | e2e p50 \
         {} us p99 {} us",
        wall.as_secs_f64(),
        percentile(&lat, 50.0),
        percentile(&lat, 99.0),
    );
    if span_frac > 0.0 {
        println!(
            "  classify: {} ok / {} failed — span: {} ok / {} failed",
            clf.ok, clf.failed, spn.ok, spn.failed
        );
    }
    if let Some((_, stats)) = &mid_stats {
        let dispatched = stats
            .path(&["merged", "rows_dispatched"])
            .and_then(|v| v.as_f64())
            .unwrap_or(0.0);
        println!("mid-flight /stats: {dispatched} rows dispatched");
    }
    if mixed_len {
        // surface the continuous-batching observables the smoke job
        // asserts on
        if let Ok((_, s)) =
            HttpClient::connect(&addr).and_then(|mut c| c.get("/stats"))
        {
            let frac = s
                .path(&["merged", "padded_token_fraction"])
                .and_then(|v| v.as_f64())
                .unwrap_or(-1.0);
            let shed = s
                .path(&["server", "rejected_429"])
                .and_then(|v| v.as_f64())
                .unwrap_or(-1.0);
            println!(
                "mixed-length: padded_token_fraction {frac:.3}, \
                 rejected_429 {shed}"
            );
        }
    }

    // final /stats from the server's point of view
    let (_, final_stats) =
        HttpClient::connect(&addr).and_then(|mut c| c.get("/stats"))?;
    let summary = Json::obj(vec![
        ("target", Json::str(addr.clone())),
        ("requests", Json::num(total as f64)),
        ("connections", Json::num(conns as f64)),
        ("ok", Json::num(ok as f64)),
        ("failed", Json::num(failed as f64)),
        (
            "tasks",
            Json::obj(vec![
                (
                    "classify",
                    Json::obj(vec![
                        ("ok", Json::num(clf.ok as f64)),
                        ("failed", Json::num(clf.failed as f64)),
                    ]),
                ),
                (
                    "span",
                    Json::obj(vec![
                        ("ok", Json::num(spn.ok as f64)),
                        ("failed", Json::num(spn.failed as f64)),
                    ]),
                ),
            ]),
        ),
        ("mixed_len", Json::Bool(mixed_len)),
        ("span_frac", Json::num(span_frac)),
        ("wall_s", Json::num(wall.as_secs_f64())),
        ("rps", Json::num(rps)),
        (
            "latency_us",
            Json::obj(vec![
                ("p50", Json::num(percentile(&lat, 50.0) as f64)),
                ("p90", Json::num(percentile(&lat, 90.0) as f64)),
                ("p99", Json::num(percentile(&lat, 99.0) as f64)),
            ]),
        ),
        ("server_stats", final_stats),
    ]);
    if let Some(dir) = std::path::Path::new(&out).parent() {
        std::fs::create_dir_all(dir)?;
    }
    std::fs::write(&out, summary.to_string_pretty())?;
    println!("wrote {out}");

    if let Some(server) = server {
        let report = server.shutdown()?;
        report.print_summary();
        assert_eq!(
            report.pool_reports.iter().map(|r| r.requests).sum::<u64>(),
            ok,
            "every 200 must correspond to exactly one served request"
        );
    }
    Ok(())
}
