//! Design-space exploration scenario (paper Sec. V-C / Fig. 16): sweep
//! PE count x net buffer size x a dataflow pair for BERT-Tiny on the
//! Edge template through the parallel `sim::dse` sweep, print the
//! stall/objective surface, and report the Pareto frontier + knee point
//! next to the paper's chosen configuration.
//!
//! Prefers the measured sparsity trace at `reports/sparsity_trace.json`
//! (run `acceltran trace` to capture one); falls back to the assumed
//! uniform profile otherwise.  `acceltran dse` is the scriptable
//! version of this scenario.
//!
//! Run with: `cargo run --release --example design_space`

use acceltran::model::TransformerConfig;
use acceltran::sim::dataflow::Dataflow;
use acceltran::sim::engine::{SparsityProfile, SparsitySource};
use acceltran::sim::scheduler::Policy;
use acceltran::sim::{dse, AcceleratorConfig};
use acceltran::trace::SparsityTrace;
use acceltran::util::table::{eng, Table};

fn main() {
    let model = TransformerConfig::bert_tiny();
    let seq = 128;

    let trace_path = "reports/sparsity_trace.json";
    let source = match SparsityTrace::load(trace_path) {
        Ok(t) => {
            println!("sparsity: measured trace {trace_path}");
            SparsitySource::Trace(t)
        }
        Err(_) => {
            println!(
                "sparsity: uniform assumed profile (no trace at {trace_path}; \
                 run `acceltran trace` to capture one)"
            );
            SparsitySource::Uniform(SparsityProfile::paper_default())
        }
    };

    let mut space = dse::DseSpace::around(AcceleratorConfig::edge());
    space.pes = vec![32, 64, 128, 256];
    space.buffers_mb = vec![10, 13, 16];
    // The paper's pick plus the worst-reuse order from Fig. 15, so the
    // energy axis shows the dataflow term too.
    space.dataflows = vec![
        Dataflow::parse("bijk").unwrap(),
        Dataflow::parse("kjib").unwrap(),
    ];

    println!(
        "sweeping {} design points of {} on {} @ seq {seq}\n",
        space.len(),
        space.base.name,
        model.name
    );
    let report = dse::sweep(
        &space,
        &model,
        seq,
        Policy::Staggered,
        &source,
        &dse::SweepOptions { threads: 0, progress: true },
    );

    let mut t = Table::new([
        "PEs",
        "buf MB",
        "dataflow",
        "cycles",
        "seq/s",
        "mJ/seq",
        "mm^2",
        "frontier",
    ]);
    for p in &report.points {
        t.row([
            p.pes.to_string(),
            p.buffer_mb.to_string(),
            p.dataflow.clone(),
            eng(p.result.total_cycles as f64),
            eng(p.throughput_seq_s),
            format!("{:.3}", p.energy_mj_per_seq),
            format!("{:.1}", p.area_mm2),
            (if report.frontier.contains(p.index) { "*" } else { "" }).to_string(),
        ]);
    }
    t.print();

    let knee = report.knee_point().expect("non-empty sweep has a knee");
    println!(
        "\nPareto frontier: {} of {} points; knee point {} \
         ({} seq/s, {:.3} mJ/seq, {:.1} mm^2)",
        report.frontier.indices.len(),
        report.points.len(),
        knee.config_name,
        eng(knee.throughput_seq_s),
        knee.energy_mj_per_seq,
        knee.area_mm2
    );
    println!(
        "the paper selects 64 PEs / 13 MB / bijk by the same trade-off \
         (Sec. V-C); `acceltran dse` writes the full report to \
         reports/dse_frontier.json"
    );
}
