//! Design-space exploration scenario (paper Sec. V-C / Fig. 16): sweep
//! PE count x net buffer size for BERT-Tiny on the Edge template, print
//! the stall surface, and recommend the paper's chosen point.
//!
//! Run with: `cargo run --release --example design_space`

use acceltran::model::TransformerConfig;
use acceltran::sim::engine::{simulate, SparsityProfile};
use acceltran::sim::scheduler::Policy;
use acceltran::sim::AcceleratorConfig;
use acceltran::util::table::{eng, Table};

fn main() {
    let model = TransformerConfig::bert_tiny();
    let seq = 128;
    let sp = SparsityProfile::paper_default();
    let pes_grid = [32usize, 64, 128, 256];
    let buf_grid = [10usize, 13, 16];

    let mut t = Table::new([
        "PEs",
        "buffer MB",
        "compute stalls",
        "memory stalls",
        "cycles",
        "area-proxy (PEs x MB)",
    ]);
    let mut results = Vec::new();
    for &pes in &pes_grid {
        for &buf in &buf_grid {
            let mut cfg = AcceleratorConfig::edge();
            cfg.pes = pes;
            // the paper's 4:8:1 activation:weight:mask split (Sec. V-C)
            let unit = (buf << 20) / 13;
            cfg.act_buffer_bytes = 4 * unit;
            cfg.weight_buffer_bytes = 8 * unit;
            cfg.mask_buffer_bytes = unit;
            let r = simulate(&cfg, &model, seq, Policy::Staggered, sp);
            t.row([
                pes.to_string(),
                buf.to_string(),
                eng(r.stalls.compute_total() as f64),
                eng(r.stalls.memory_total() as f64),
                eng(r.total_cycles as f64),
                (pes * buf).to_string(),
            ]);
            results.push((pes, buf, r));
        }
    }
    t.print();

    // Chosen-point logic: smallest (PEs x buffer) whose cycle count is
    // within 10% of the best observed — the Fig. 16 trade-off argument.
    let best_cycles = results.iter().map(|(_, _, r)| r.total_cycles).min().unwrap();
    let chosen = results
        .iter()
        .filter(|(_, _, r)| r.total_cycles as f64 <= best_cycles as f64 * 1.1)
        .min_by_key(|(pes, buf, _)| pes * buf)
        .unwrap();
    println!(
        "\nchosen point: {} PEs, {} MB net buffer (cycles {} vs best {}) — \
         the paper selects 64 PEs / 13 MB by the same trade-off",
        chosen.0,
        chosen.1,
        eng(chosen.2.total_cycles as f64),
        eng(best_cycles as f64)
    );
}
