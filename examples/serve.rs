//! Serving scenario: stream classification requests through the dynamic
//! batcher with DynaTran on vs off, reporting throughput and latency
//! percentiles — the coordinator-level view of the paper's dynamic
//! inference story.  Runs out of the box on the reference backend; uses
//! PJRT artifacts when present.
//!
//! Run with: `cargo run --release --example serve -- [n_requests]`

use acceltran::coordinator::BatchServer;
use acceltran::nlp::sentiment::SentimentTask;
use acceltran::runtime::{ParamStore, Runtime};
use anyhow::Result;

fn run_wave(server: &mut BatchServer, reqs: &[(Vec<i32>, f32)]) -> Result<f64> {
    let t0 = std::time::Instant::now();
    let mut served = 0usize;
    for (ids, tau) in reqs {
        server.submit(ids.clone(), *tau);
        served += server.step()?.len();
    }
    served += server.drain()?.len();
    assert_eq!(served, reqs.len());
    Ok(served as f64 / t0.elapsed().as_secs_f64())
}

fn main() -> Result<()> {
    let n: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(512);
    let rt = Runtime::load_default()?;
    let vocab = rt.manifest.vocab;
    let seq = rt.manifest.seq;
    println!("serving on the '{}' backend", rt.backend_name());
    let params = ParamStore::init(&rt.manifest, 0).params;
    let mut server = BatchServer::new(rt, params);

    let task = SentimentTask::new(vocab, seq, 11);
    let ds = task.dataset(n, 5);

    for (label, tau) in [("DynaTran off (tau=0)", 0.0f32), ("DynaTran on (tau=0.05)", 0.05)] {
        let reqs: Vec<(Vec<i32>, f32)> =
            ds.examples.iter().map(|e| (e.ids.clone(), tau)).collect();
        let rps = run_wave(&mut server, &reqs)?;
        let s = &server.stats;
        println!(
            "{label:<24} {rps:>8.1} req/s | dispatch latency mean {:?} p50 {:?} p99 {:?} | \
             {} dispatches, {:.1}% padded rows, queue high-water {}",
            s.mean_latency(),
            s.latency_percentile(50.0),
            s.latency_percentile(99.0),
            s.dispatches,
            100.0 * s.padded_row_fraction(),
            s.queue_depth_high_water
        );
        server.stats = Default::default();
    }
    println!(
        "\n(functional host-CPU numbers; the ASIC-level serving speedups are\n\
         produced by the simulator — see `acceltran simulate` and benches/)"
    );
    Ok(())
}
