//! Serving scenario: stream classification requests through the
//! concurrent serving engine — a pool of workers (one forked backend
//! each) draining a shared queue under deadline-aware dynamic batching —
//! and compare worker counts and DynaTran on vs off.  Runs out of the
//! box on the reference backend; uses PJRT artifacts when present.
//!
//! Run with: `cargo run --release --example serve -- [n_requests]`
//!
//! The per-worker host parallelism interacts with the reference
//! backend's own row-parallel GEMMs: set `ACCELTRAN_THREADS=1` to give
//! each worker one core and see pure pool scaling (the
//! `serve_throughput` bench does exactly that).

use std::time::Duration;

use acceltran::coordinator::{ServeConfig, ServePool, ServeReport};
use acceltran::nlp::sentiment::SentimentTask;
use acceltran::runtime::{ParamStore, Runtime};
use anyhow::Result;

fn run_wave(
    rt: &Runtime,
    params: &[f32],
    reqs: &[(Vec<i32>, f32)],
    workers: usize,
) -> Result<ServeReport> {
    let cfg = ServeConfig {
        workers,
        slo: Duration::from_millis(10),
        sim: None,
        // the example submits its whole wave up front, so lift the
        // admission bound out of the way (a real front-end would let
        // QueueFull push back — the HTTP server answers 429)
        max_queue: reqs.len().max(1),
        ..Default::default()
    };
    let pool = ServePool::start(rt, params, &cfg)?;
    for (ids, tau) in reqs {
        pool.submit(ids.clone(), *tau)?;
    }
    let (report, responses) = pool.finish()?;
    assert_eq!(responses.len(), reqs.len());
    Ok(report)
}

fn main() -> Result<()> {
    let n: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(512);
    let rt = Runtime::load_default()?;
    let vocab = rt.manifest.vocab;
    let seq = rt.manifest.seq;
    println!("serving on the '{}' backend\n", rt.backend_name());
    let params = ParamStore::init(&rt.manifest, 0).params;

    let task = SentimentTask::new(vocab, seq, 11);
    let ds = task.dataset(n, 5);

    // 1. pool scaling at a fixed operating point
    println!("-- worker-pool scaling (tau=0.05, {n} requests) --");
    for workers in [1usize, 2, 4] {
        let reqs: Vec<(Vec<i32>, f32)> =
            ds.examples.iter().map(|e| (e.ids.clone(), 0.05)).collect();
        let r = run_wave(&rt, &params, &reqs, workers)?;
        println!(
            "{workers} worker(s): {:>8.1} req/s | total latency p50 {:>7} us \
             p99 {:>7} us | {} dispatches, {:.1}% padded, high-water {}",
            r.throughput_rps(),
            r.total_latency.percentile_us(50.0),
            r.total_latency.percentile_us(99.0),
            r.stats.dispatches,
            100.0 * r.stats.padded_row_fraction(),
            r.stats.queue_depth_high_water
        );
    }

    // 2. DynaTran on vs off on the full pool (the dynamic-inference story)
    println!("\n-- DynaTran off vs on (4 workers) --");
    for (label, tau) in [("DynaTran off (tau=0)", 0.0f32), ("DynaTran on (tau=0.05)", 0.05)] {
        let reqs: Vec<(Vec<i32>, f32)> =
            ds.examples.iter().map(|e| (e.ids.clone(), tau)).collect();
        let r = run_wave(&rt, &params, &reqs, 4)?;
        println!(
            "{label:<24} {:>8.1} req/s | compute p50 {:>7} us  queue p50 {:>7} us",
            r.throughput_rps(),
            r.compute_latency.percentile_us(50.0),
            r.queue_latency.percentile_us(50.0)
        );
    }
    // 3. mixed-length wave: requests shorter than manifest.seq are
    //    batched per length bucket and padded only to the bucket width,
    //    so most dispatched tokens are real work
    println!("\n-- mixed-length wave (lens 1..={seq}, 4 workers) --");
    let reqs: Vec<(Vec<i32>, f32)> = ds
        .examples
        .iter()
        .enumerate()
        .map(|(i, e)| (e.ids[..1 + i % seq].to_vec(), 0.05f32))
        .collect();
    let r = run_wave(&rt, &params, &reqs, 4)?;
    println!(
        "{:>8.1} req/s | {} dispatches | padded tokens {:.1}% (vs ~{:.0}% if \
         every row were padded to seq={seq})",
        r.throughput_rps(),
        r.stats.dispatches,
        100.0 * r.stats.padded_token_fraction(),
        100.0 * (1.0 - (seq as f64 + 1.0) / (2.0 * seq as f64)),
    );
    println!(
        "\n(functional host-CPU numbers; `acceltran serve --sim-in-loop` adds\n\
         the modeled-accelerator latency per batch, and the ASIC-level\n\
         serving speedups come from the simulator — see benches/)"
    );
    Ok(())
}
