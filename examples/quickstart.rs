//! Quickstart: the two halves of AccelTran in one page.
//!
//! 1. **Functional path** — classify a batch at two DynaTran thresholds
//!    through the runtime.  Runs out of the box on the pure-Rust
//!    reference executor; loads the AOT/PJRT artifacts instead when they
//!    are present (or when `ACCELTRAN_BACKEND=pjrt`).
//! 2. **Timing path** — simulate the same model on AccelTran-Edge and
//!    print throughput / energy / utilization.
//!
//! Run with: `cargo run --release --example quickstart`

use acceltran::model::TransformerConfig;
use acceltran::nlp::sentiment::SentimentTask;
use acceltran::runtime::{ParamStore, Runtime};
use acceltran::sim::engine::{simulate, SparsityProfile};
use acceltran::sim::scheduler::Policy;
use acceltran::sim::AcceleratorConfig;
use acceltran::util::table::eng;
use anyhow::Result;

fn main() -> Result<()> {
    // ---- functional path: runtime inference ---------------------------
    let mut rt = Runtime::load_default()?;
    println!(
        "loaded {} ({} params) on the '{}' backend",
        rt.manifest.model_name,
        rt.manifest.param_count,
        rt.backend_name(),
    );
    let params = ParamStore::init(&rt.manifest, 0);
    let task = SentimentTask::new(rt.manifest.vocab, rt.manifest.seq, 7);
    let ds = task.dataset(8, 1);
    let mut ids = Vec::new();
    for ex in &ds.examples {
        ids.extend_from_slice(&ex.ids);
    }
    for tau in [0.0f32, 0.05] {
        let t0 = std::time::Instant::now();
        let logits = rt.classify(8, &params.params, &ids, tau)?;
        let rho = rt.activation_sparsity(&params.params, &ids, tau)?;
        println!(
            "tau={tau:<5} activation sparsity {rho:.3}  first logits {:?}  ({:?})",
            &logits[..2],
            t0.elapsed()
        );
    }

    // ---- timing path: cycle-accurate simulation -----------------------
    let cfg = AcceleratorConfig::edge();
    let model = TransformerConfig::bert_tiny();
    let r = simulate(&cfg, &model, 128, Policy::Staggered,
                     SparsityProfile::paper_default());
    println!(
        "\nAccelTran-Edge x {} @ seq 128, batch {}:",
        model.name, cfg.batch
    );
    println!("  cycles        {}", eng(r.total_cycles as f64));
    println!("  latency       {:.3} ms", 1e3 * r.latency_s(&cfg));
    println!("  throughput    {} seq/s", eng(r.throughput_seq_s(&cfg)));
    println!("  energy        {:.3} mJ/seq", r.energy_mj_per_seq());
    println!("  avg power     {:.2} W", r.avg_power_w(&cfg));
    println!(
        "  utilization   MAC {:.1}%  softmax {:.1}%  DMA {:.1}%",
        100.0 * r.mac_utilization,
        100.0 * r.softmax_utilization,
        100.0 * r.dma_utilization
    );
    Ok(())
}
