"""L2: encoder-only transformer (BERT-family-shaped) in JAX.

This is the model side of the AccelTran reproduction: the exact op sequence
of the paper's Table I (M-OP-0 embeddings+position, per-layer C-OP-1..11:
QKV projections, scaled-dot-product attention with softmax, output
projection, add+layer-norm, two feed-forward GeLU layers, layer-norm),
with two dynamic-inference hooks threaded through the graph:

* **DynaTran** (the paper's contribution): every activation matrix is
  magnitude-thresholded at a runtime scalar ``tau`` (Sec. III-A).
* **top-k** (the SpAtten-style baseline): attention rows keep only the
  top ``keep_frac * N`` scores (expressed as a traced quantile threshold
  so one artifact serves the whole Fig. 11(b) sweep).

Parameters live in ONE flat f32 vector.  The Rust coordinator owns that
buffer (init, optimizer state, persistence); ``param_specs`` publishes the
layout so both sides agree.  This keeps the PJRT call signature trivial:
``classify(params, ids, tau) -> logits`` and
``train_step(params, m, v, step, ids, labels, lr) -> (params', m', v', loss)``.

Everything here is build-time only: ``aot.py`` lowers jitted wrappers to
HLO text once; Python never appears on the Rust request path.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable

import jax
import jax.numpy as jnp

from .kernels import ref
from .kernels import dynatran as k_dynatran
from .kernels import layernorm as k_layernorm
from .kernels import matmul as k_matmul
from .kernels import softmax as k_softmax


# --------------------------------------------------------------------------
# Configuration
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture hyper-parameters (paper Sec. IV-A naming).

    ``bert_tiny()`` matches BERT-Tiny's shape (h=128, 2 layers, 2 heads);
    the vocabulary is the synthetic-sentiment tokenizer's (the 30,522-entry
    WordPiece vocab of the paper needs the proprietary-scale corpus; see
    DESIGN.md §Substitutions).
    """

    name: str = "bert-tiny-synth"
    vocab: int = 1024
    seq: int = 64
    hidden: int = 128
    layers: int = 2
    heads: int = 2
    ff: int = 512
    classes: int = 2

    @property
    def head_dim(self) -> int:
        assert self.hidden % self.heads == 0
        return self.hidden // self.heads

    @staticmethod
    def bert_tiny(vocab: int = 1024, seq: int = 64,
                  classes: int = 2) -> "ModelConfig":
        return ModelConfig(name="bert-tiny-synth", vocab=vocab, seq=seq,
                           hidden=128, layers=2, heads=2, ff=512,
                           classes=classes)

    @staticmethod
    def bert_mini(vocab: int = 1024, seq: int = 64,
                  classes: int = 2) -> "ModelConfig":
        return ModelConfig(name="bert-mini-synth", vocab=vocab, seq=seq,
                           hidden=256, layers=4, heads=4, ff=1024,
                           classes=classes)


# --------------------------------------------------------------------------
# Flat parameter layout
# --------------------------------------------------------------------------

def param_specs(cfg: ModelConfig) -> list[tuple[str, tuple[int, ...], float]]:
    """(name, shape, init_std) for every parameter, in flat-buffer order.

    The Rust side reads this layout from ``artifacts/manifest.json`` and
    initializes/owns the flat buffer; slicing here must match exactly.
    """
    h, f = cfg.hidden, cfg.ff
    specs: list[tuple[str, tuple[int, ...], float]] = [
        ("embed.word", (cfg.vocab, h), 0.02),
        ("embed.pos", (cfg.seq, h), 0.02),
    ]
    for layer in range(cfg.layers):
        p = f"layer{layer}"
        std = 0.02
        specs += [
            (f"{p}.attn.wq", (h, h), std),
            (f"{p}.attn.bq", (h,), 0.0),
            (f"{p}.attn.wk", (h, h), std),
            (f"{p}.attn.bk", (h,), 0.0),
            (f"{p}.attn.wv", (h, h), std),
            (f"{p}.attn.bv", (h,), 0.0),
            (f"{p}.attn.wo", (h, h), std),
            (f"{p}.attn.bo", (h,), 0.0),
            (f"{p}.ln1.gamma", (h,), -1.0),   # init_std < 0 => init to 1.0
            (f"{p}.ln1.beta", (h,), 0.0),
            (f"{p}.ffn.w1", (h, f), std),
            (f"{p}.ffn.b1", (f,), 0.0),
            (f"{p}.ffn.w2", (f, h), std),
            (f"{p}.ffn.b2", (h,), 0.0),
            (f"{p}.ln2.gamma", (h,), -1.0),
            (f"{p}.ln2.beta", (h,), 0.0),
        ]
    specs += [
        ("cls.w", (h, cfg.classes), 0.02),
        ("cls.b", (cfg.classes,), 0.0),
    ]
    return specs


def param_count(cfg: ModelConfig) -> int:
    return sum(math.prod(shape) for _, shape, _ in param_specs(cfg))


def unpack_params(cfg: ModelConfig, flat: jax.Array) -> dict[str, jax.Array]:
    """Slice the flat buffer into named, shaped parameter arrays."""
    params: dict[str, jax.Array] = {}
    off = 0
    for name, shape, _ in param_specs(cfg):
        n = math.prod(shape)
        params[name] = jax.lax.dynamic_slice_in_dim(flat, off, n).reshape(shape)
        off += n
    return params


def init_params(cfg: ModelConfig, seed: int = 0) -> jax.Array:
    """Reference initializer (tests / python-side experiments).  The Rust
    coordinator performs the same per-spec init with its own PRNG."""
    key = jax.random.PRNGKey(seed)
    chunks = []
    for name, shape, std in param_specs(cfg):
        key, sub = jax.random.split(key)
        n = math.prod(shape)
        if std < 0:        # layer-norm gain: ones
            chunks.append(jnp.ones((n,), jnp.float32))
        elif std == 0.0:   # biases: zeros
            chunks.append(jnp.zeros((n,), jnp.float32))
        else:
            chunks.append(std * jax.random.normal(sub, (n,), jnp.float32))
    return jnp.concatenate(chunks)


# --------------------------------------------------------------------------
# Forward pass
# --------------------------------------------------------------------------

PRUNE_NONE = "none"
PRUNE_DYNATRAN = "dynatran"
PRUNE_TOPK = "topk"


def _ops(use_pallas: bool):
    """Select the kernel set: L1 Pallas kernels (numerics-validation
    artifacts) or the pure-jnp oracles (fast fused serving artifacts)."""
    if use_pallas:
        return dict(
            matmul=lambda x, y: k_matmul.matmul_fullk(x, y, bm=16, bn=16),
            softmax=k_softmax.softmax,
            layernorm=k_layernorm.layernorm,
            prune=k_dynatran.prune_only,
        )
    return dict(
        matmul=ref.matmul,
        softmax=ref.softmax,
        layernorm=ref.layernorm,
        prune=lambda x, tau: ref.dynatran_prune(x, tau)[0],
    )


def encoder_forward(cfg: ModelConfig, flat_params: jax.Array,
                    ids: jax.Array, tau: jax.Array,
                    keep_frac: jax.Array,
                    prune_mode: str = PRUNE_DYNATRAN,
                    use_pallas: bool = False) -> jax.Array:
    """Run the encoder stack; returns the (B, S, H) hidden states.

    ``tau`` only has effect under DynaTran mode; ``keep_frac`` only under
    top-k mode.  ``tau == 0`` / ``keep_frac == 1`` are exact no-ops, so the
    unpruned baseline is the same artifact evaluated at the identity point.
    """
    ops = _ops(use_pallas)
    p = unpack_params(cfg, flat_params)
    B, S = ids.shape
    H, nh, hd = cfg.hidden, cfg.heads, cfg.head_dim
    scale = 1.0 / math.sqrt(hd)

    def prune_act(x2d: jax.Array) -> jax.Array:
        """DynaTran hook on an activation matrix (paper prunes *all*
        activations, not just attention scores — its key delta vs SpAtten
        and Energon)."""
        if prune_mode == PRUNE_DYNATRAN:
            return ops["prune"](x2d, tau)
        return x2d

    # M-OP-0: embeddings + position encodings.
    hemb = jnp.take(p["embed.word"], ids, axis=0)          # (B, S, H)
    hidden = hemb + p["embed.pos"][None, :, :]

    for layer in range(cfg.layers):
        lp = f"layer{layer}"
        x2 = hidden.reshape(B * S, H)
        x2 = prune_act(x2)

        # C-OP-1..3: QKV projections (per-head weights fused into h x h).
        q = prune_act(ops["matmul"](x2, p[f"{lp}.attn.wq"]) + p[f"{lp}.attn.bq"])
        k = prune_act(ops["matmul"](x2, p[f"{lp}.attn.wk"]) + p[f"{lp}.attn.bk"])
        v = prune_act(ops["matmul"](x2, p[f"{lp}.attn.wv"]) + p[f"{lp}.attn.bv"])

        qh = q.reshape(B, S, nh, hd).transpose(0, 2, 1, 3)  # (B, nh, S, hd)
        kh = k.reshape(B, S, nh, hd).transpose(0, 2, 1, 3)
        vh = v.reshape(B, S, nh, hd).transpose(0, 2, 1, 3)

        # C-OP-4..5: attention scores + softmax.  Batched heads are folded
        # into the row dimension so the 2-D tiled kernels apply unchanged.
        a = jnp.einsum("bnsd,bntd->bnst", qh, kh) * scale    # (B, nh, S, S)
        a2 = a.reshape(B * nh * S, S)
        if prune_mode == PRUNE_TOPK:
            a2 = ref.topk_keep_fraction(a2, keep_frac)
        else:
            a2 = prune_act(a2)
        s2 = ops["softmax"](a2)
        s = s2.reshape(B, nh, S, S)

        # C-OP-6: probabilities x values.
        ph = jnp.einsum("bnst,bntd->bnsd", s, vh)            # (B, nh, S, hd)
        pcat = ph.transpose(0, 2, 1, 3).reshape(B * S, H)
        pcat = prune_act(pcat)

        # C-OP-7: output projection.
        mha = ops["matmul"](pcat, p[f"{lp}.attn.wo"]) + p[f"{lp}.attn.bo"]
        mha = prune_act(mha)

        # C-OP-8: residual add + layer-norm.
        x_ln1 = ops["layernorm"](mha + x2, p[f"{lp}.ln1.gamma"],
                                 p[f"{lp}.ln1.beta"])

        # C-OP-9..10: feed-forward with GeLU (GeLU fused at MAC-lane output).
        f1 = ref.gelu(ops["matmul"](prune_act(x_ln1), p[f"{lp}.ffn.w1"])
                      + p[f"{lp}.ffn.b1"])
        f1 = prune_act(f1)
        f2 = ops["matmul"](f1, p[f"{lp}.ffn.w2"]) + p[f"{lp}.ffn.b2"]
        f2 = prune_act(f2)

        # C-OP-11: layer-norm (residual from x_ln1, standard post-LN BERT).
        out = ops["layernorm"](f2 + x_ln1, p[f"{lp}.ln2.gamma"],
                               p[f"{lp}.ln2.beta"])
        hidden = out.reshape(B, S, H)

    return hidden


def classify(cfg: ModelConfig, flat_params: jax.Array, ids: jax.Array,
             tau: jax.Array, keep_frac: jax.Array,
             prune_mode: str = PRUNE_DYNATRAN,
             use_pallas: bool = False) -> jax.Array:
    """Sequence classification from the position-0 ([CLS]) token."""
    hidden = encoder_forward(cfg, flat_params, ids, tau, keep_frac,
                             prune_mode=prune_mode, use_pallas=use_pallas)
    p = unpack_params(cfg, flat_params)
    pooled = hidden[:, 0, :]                               # (B, H)
    return ref.matmul(pooled, p["cls.w"]) + p["cls.b"]


def activation_sparsity(cfg: ModelConfig, flat_params: jax.Array,
                        ids: jax.Array, tau: jax.Array) -> jax.Array:
    """Mean post-DynaTran activation sparsity over the forward pass —
    the rho axis of Figs. 11/12.  Re-runs the encoder accumulating the
    zero-fraction of every pruned activation matrix."""
    # Capture sparsities functionally by re-implementing the hook.
    acc = []

    ops = _ops(False)
    p = unpack_params(cfg, flat_params)
    B, S = ids.shape
    H, nh, hd = cfg.hidden, cfg.heads, cfg.head_dim
    scale = 1.0 / math.sqrt(hd)

    def prune_act(x2d):
        out = ops["prune"](x2d, tau)
        acc.append(ref.sparsity(out))
        return out

    hemb = jnp.take(p["embed.word"], ids, axis=0)
    hidden = hemb + p["embed.pos"][None, :, :]
    for layer in range(cfg.layers):
        lp = f"layer{layer}"
        x2 = prune_act(hidden.reshape(B * S, H))
        q = prune_act(ops["matmul"](x2, p[f"{lp}.attn.wq"]) + p[f"{lp}.attn.bq"])
        k = prune_act(ops["matmul"](x2, p[f"{lp}.attn.wk"]) + p[f"{lp}.attn.bk"])
        v = prune_act(ops["matmul"](x2, p[f"{lp}.attn.wv"]) + p[f"{lp}.attn.bv"])
        qh = q.reshape(B, S, nh, hd).transpose(0, 2, 1, 3)
        kh = k.reshape(B, S, nh, hd).transpose(0, 2, 1, 3)
        vh = v.reshape(B, S, nh, hd).transpose(0, 2, 1, 3)
        a = jnp.einsum("bnsd,bntd->bnst", qh, kh) * scale
        a2 = prune_act(a.reshape(B * nh * S, S))
        s = ops["softmax"](a2).reshape(B, nh, S, S)
        ph = jnp.einsum("bnst,bntd->bnsd", s, vh)
        pcat = prune_act(ph.transpose(0, 2, 1, 3).reshape(B * S, H))
        mha = prune_act(ops["matmul"](pcat, p[f"{lp}.attn.wo"]) + p[f"{lp}.attn.bo"])
        x_ln1 = ops["layernorm"](mha + x2, p[f"{lp}.ln1.gamma"], p[f"{lp}.ln1.beta"])
        f1 = prune_act(ref.gelu(ops["matmul"](prune_act(x_ln1), p[f"{lp}.ffn.w1"])
                                + p[f"{lp}.ffn.b1"]))
        f2 = prune_act(ops["matmul"](f1, p[f"{lp}.ffn.w2"]) + p[f"{lp}.ffn.b2"])
        hidden = ops["layernorm"](f2 + x_ln1, p[f"{lp}.ln2.gamma"],
                                  p[f"{lp}.ln2.beta"]).reshape(B, S, H)
    return jnp.mean(jnp.stack(acc))


# --------------------------------------------------------------------------
# Training (AdamW on the flat buffer)
# --------------------------------------------------------------------------

ADAM_B1 = 0.9
ADAM_B2 = 0.999
ADAM_EPS = 1e-8


def loss_fn(cfg: ModelConfig, flat_params: jax.Array, ids: jax.Array,
            labels: jax.Array) -> jax.Array:
    """Mean softmax cross-entropy (training always runs unpruned)."""
    logits = classify(cfg, flat_params, ids,
                      tau=jnp.float32(0.0), keep_frac=jnp.float32(1.0),
                      prune_mode=PRUNE_NONE, use_pallas=False)
    logz = jax.nn.logsumexp(logits, axis=-1)
    picked = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    return jnp.mean(logz - picked)


def train_step(cfg: ModelConfig, flat_params: jax.Array, m: jax.Array,
               v: jax.Array, step: jax.Array, ids: jax.Array,
               labels: jax.Array, lr: jax.Array):
    """One AdamW step over the flat buffer.

    Returns ``(params', m', v', loss)``.  The optimizer state (m, v) is two
    more flat f32 buffers owned by the Rust coordinator; ``step`` is a
    float32 scalar step counter for bias correction.
    """
    loss, grads = jax.value_and_grad(
        lambda fp: loss_fn(cfg, fp, ids, labels))(flat_params)
    t = step + 1.0
    m2 = ADAM_B1 * m + (1.0 - ADAM_B1) * grads
    v2 = ADAM_B2 * v + (1.0 - ADAM_B2) * jnp.square(grads)
    mhat = m2 / (1.0 - ADAM_B1 ** t)
    vhat = v2 / (1.0 - ADAM_B2 ** t)
    upd = lr * mhat / (jnp.sqrt(vhat) + ADAM_EPS)
    return flat_params - upd, m2, v2, loss


def accuracy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    return jnp.mean((jnp.argmax(logits, axis=-1) == labels)
                    .astype(jnp.float32))
