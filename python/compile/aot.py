"""AOT compile path: lower every model variant to HLO *text* artifacts.

The interchange format is HLO text, NOT a serialized ``HloModuleProto``:
jax >= 0.5 emits protos with 64-bit instruction ids that the xla crate's
bundled xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text
parser reassigns ids, so text round-trips cleanly (see
/opt/xla-example/README.md).  Lowering uses ``return_tuple=True`` and the
Rust runtime unwraps with ``to_tuple1()`` / tuple indexing.

Usage (invoked by ``make artifacts``)::

    cd python && python -m compile.aot --out-dir ../artifacts

Emits one ``<name>.hlo.txt`` per entry of ``ARTIFACTS`` plus a
``manifest.json`` describing parameter layout, shapes, dtypes and argument
signatures for the Rust side.  Python never runs on the request path.
"""

from __future__ import annotations

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M


def to_hlo_text(lowered) -> str:
    """StableHLO module -> XlaComputation -> HLO text (id-safe path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f32(shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def i32(shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


# --------------------------------------------------------------------------
# Artifact definitions
# --------------------------------------------------------------------------

def _artifact_defs(cfg: M.ModelConfig):
    """Name -> (callable returning a tuple, example-arg ShapeDtypeStructs,
    human signature).  Every fn returns a tuple (return_tuple lowering)."""
    np_ = M.param_count(cfg)
    S = cfg.seq

    def classify_fn(params, ids, tau):
        return (M.classify(cfg, params, ids, tau, jnp.float32(1.0),
                           prune_mode=M.PRUNE_DYNATRAN),)

    def classify_topk_fn(params, ids, keep_frac):
        return (M.classify(cfg, params, ids, jnp.float32(0.0), keep_frac,
                           prune_mode=M.PRUNE_TOPK),)

    def classify_pallas_fn(params, ids, tau):
        return (M.classify(cfg, params, ids, tau, jnp.float32(1.0),
                           prune_mode=M.PRUNE_DYNATRAN, use_pallas=True),)

    def sparsity_fn(params, ids, tau):
        return (M.activation_sparsity(cfg, params, ids, tau),)

    def train_fn(params, m, v, step, ids, labels, lr):
        return M.train_step(cfg, params, m, v, step, ids, labels, lr)

    def prune_fn(x, tau):
        from .kernels import dynatran
        return tuple(dynatran.dynatran_prune(x, tau))

    defs = {}
    for batch in (1, 8, 32):
        defs[f"classify_b{batch}"] = (
            classify_fn,
            (f32((np_,)), i32((batch, S)), f32(())),
            f"(params[{np_}], ids[{batch},{S}] i32, tau) -> logits[{batch},{cfg.classes}]",
        )
    defs["classify_topk_b32"] = (
        classify_topk_fn,
        (f32((np_,)), i32((32, S)), f32(())),
        f"(params[{np_}], ids[32,{S}] i32, keep_frac) -> logits[32,{cfg.classes}]",
    )
    defs["classify_pallas_b2"] = (
        classify_pallas_fn,
        (f32((np_,)), i32((2, S)), f32(())),
        f"(params[{np_}], ids[2,{S}] i32, tau) -> logits[2,{cfg.classes}] (L1 Pallas kernels)",
    )
    defs["act_sparsity_b8"] = (
        sparsity_fn,
        (f32((np_,)), i32((8, S)), f32(())),
        f"(params[{np_}], ids[8,{S}] i32, tau) -> mean activation sparsity []",
    )
    defs["train_step_b32"] = (
        train_fn,
        (f32((np_,)), f32((np_,)), f32((np_,)), f32(()),
         i32((32, S)), i32((32,)), f32(())),
        f"(params, m, v, step, ids[32,{S}], labels[32], lr) -> (params', m', v', loss)",
    )
    defs["dynatran_prune_256x256"] = (
        prune_fn,
        (f32((256, 256)), f32(())),
        "(x[256,256], tau) -> (pruned[256,256], mask[256,256]) (L1 Pallas kernel)",
    )
    return defs


def export_all(cfg: M.ModelConfig, out_dir: str, only: list[str] | None = None,
               verbose: bool = True) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    defs = _artifact_defs(cfg)
    manifest = {
        "model": {
            "name": cfg.name,
            "vocab": cfg.vocab,
            "seq": cfg.seq,
            "hidden": cfg.hidden,
            "layers": cfg.layers,
            "heads": cfg.heads,
            "ff": cfg.ff,
            "classes": cfg.classes,
            "param_count": M.param_count(cfg),
        },
        "params": [
            {"name": n, "shape": list(s), "init_std": std}
            for n, s, std in M.param_specs(cfg)
        ],
        "artifacts": {},
    }
    for name, (fn, args, sig) in defs.items():
        if only and name not in only:
            continue
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        lowered = jax.jit(fn).lower(*args)
        text = to_hlo_text(lowered)
        with open(path, "w") as f:
            f.write(text)
        manifest["artifacts"][name] = {
            "file": f"{name}.hlo.txt",
            "signature": sig,
            "args": [
                {"shape": list(a.shape), "dtype": a.dtype.name} for a in args
            ],
            "hlo_bytes": len(text),
        }
        if verbose:
            print(f"  wrote {path} ({len(text)} bytes)  {sig}")
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    if verbose:
        print(f"  wrote {os.path.join(out_dir, 'manifest.json')}")
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--model", default="bert-tiny",
                    choices=["bert-tiny", "bert-mini"])
    ap.add_argument("--vocab", type=int, default=1024)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--only", nargs="*", default=None,
                    help="restrict to named artifacts")
    args = ap.parse_args()
    mk = (M.ModelConfig.bert_tiny if args.model == "bert-tiny"
          else M.ModelConfig.bert_mini)
    cfg = mk(vocab=args.vocab, seq=args.seq)
    print(f"AOT-lowering {cfg.name}: h={cfg.hidden} L={cfg.layers} "
          f"heads={cfg.heads} params={M.param_count(cfg)}")
    export_all(cfg, args.out_dir, only=args.only)


if __name__ == "__main__":
    main()
