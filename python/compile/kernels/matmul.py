"""L1 Pallas kernel: tiled matrix multiplication (paper Sec. III-B1, Fig. 3).

AccelTran's core insight on the compute side is that every transformer
matmul should be decomposed into small tiles (paper uses 1 x 16 x 16 along
b/i/j) streamed to MAC lanes under a chosen dataflow.  On a TPU the same
insight maps to BlockSpec: the grid is the paper's (i, j, k) loop nest, the
BlockSpec index maps are the dataflow, and VMEM plays the role of the PE's
local registers.  The [b, i, j, k] dataflow selected by the paper (Fig. 15)
corresponds to the grid iteration order used here: k innermost maximizes
accumulator locality, j then i outermost reuse the weight strip — the exact
reuse pattern the paper's MAC lanes exploit.

Two variants:

* ``matmul_tiled`` — the canonical (i, j, k) accumulation kernel, the real
  TPU pattern (k-revisits accumulate into the output block).
* ``matmul_fullk`` — (i, j) grid with full-K strips; fewer grid steps, used
  by the L2 model under interpret mode where grid overhead dominates.

Both are verified against ``ref.matmul`` (pytest + hypothesis shape sweeps).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Paper tile sizes: b=1, i=16, j=16 (Sec. IV-B); K block chosen to match.
DEFAULT_BM = 16
DEFAULT_BN = 16
DEFAULT_BK = 16


def _matmul_kernel(x_ref, y_ref, o_ref, *, nk: int):
    """Grid step (i, j, k): accumulate one (bm, bk) @ (bk, bn) product.

    The output BlockSpec maps every k to the same (i, j) block, so the
    o_ref revisits accumulate — the MAC-lane adder-tree accumulation, one
    tile-pair per step (a "MAC lane" consumes b*x*y*z / M cycles per tile
    pair; the Rust cycle model charges exactly that).
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(x_ref[...], y_ref[...],
                          preferred_element_type=o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def matmul_tiled(x: jax.Array, y: jax.Array,
                 bm: int = DEFAULT_BM, bn: int = DEFAULT_BN,
                 bk: int = DEFAULT_BK) -> jax.Array:
    """Tiled GEMM: x (M, K) @ y (K, N) with an (i, j, k) grid."""
    m, k = x.shape
    k2, n = y.shape
    if k != k2:
        raise ValueError(f"inner dims mismatch: {k} vs {k2}")
    for dim, blk, name in ((m, bm, "M"), (n, bn, "N"), (k, bk, "K")):
        if dim % blk != 0:
            raise ValueError(f"{name}={dim} not divisible by block {blk}")
    grid = (m // bm, n // bn, k // bk)
    kernel = functools.partial(_matmul_kernel, nk=grid[2])
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=True,
    )(x, y)


def _matmul_fullk_kernel(x_ref, y_ref, o_ref):
    o_ref[...] = jnp.dot(x_ref[...], y_ref[...],
                         preferred_element_type=o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn"))
def matmul_fullk(x: jax.Array, y: jax.Array,
                 bm: int = 32, bn: int = 32) -> jax.Array:
    """Tiled GEMM with full-K strips: grid (i, j), block (bm, K) @ (K, bn).

    Used inside the AOT model artifacts: interpret-mode grid steps are
    emulated with HLO while-loops, so fewer/fatter steps run much faster on
    the CPU validation path while keeping the same VMEM-resident tiling
    structure a TPU build would use for these (small-K) projections.
    """
    m, k = x.shape
    k2, n = y.shape
    if k != k2:
        raise ValueError(f"inner dims mismatch: {k} vs {k2}")
    if m % bm != 0 or n % bn != 0:
        raise ValueError(f"M={m}/N={n} not divisible by blocks {bm}/{bn}")
    grid = (m // bm, n // bn)
    return pl.pallas_call(
        _matmul_fullk_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=True,
    )(x, y)


def vmem_bytes(bm: int, bn: int, bk: int, dtype_bytes: int = 4) -> int:
    """Static VMEM footprint of one ``matmul_tiled`` grid step (x block +
    y block + output accumulator).  Used by the §Perf analysis to size
    blocks against the ~16 MiB/core TPU VMEM budget."""
    return dtype_bytes * (bm * bk + bk * bn + bm * bn)


def mxu_utilization(bm: int, bn: int, bk: int, mxu: int = 128) -> float:
    """Fraction of a (mxu x mxu) systolic pass the block actually fills —
    the §Perf MXU-utilization estimate for one grid step."""
    fill = (min(bm, mxu) / mxu) * (min(bn, mxu) / mxu) * (min(bk, mxu) / mxu)
    return fill
