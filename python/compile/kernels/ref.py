"""Pure-jnp reference oracles for every Pallas kernel in this package.

These are the *correctness ground truth* for the L1 layer: pytest (with
hypothesis sweeps over shapes/thresholds) asserts `assert_allclose` between
each Pallas kernel (run with interpret=True) and the function of the same
name here.  They are also used directly by the L2 model when
``use_pallas=False`` (the fast pure-XLA path exported for the Rust serving
hot loop).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "dynatran_prune",
    "sparsity",
    "matmul",
    "gelu",
    "softmax",
    "layernorm",
    "attention",
    "topk_keep_fraction",
]


def dynatran_prune(x: jax.Array, tau) -> tuple[jax.Array, jax.Array]:
    """DynaTran magnitude pruning (paper Sec. III-A).

    Zeroes every element with ``|x| < tau`` and returns ``(pruned, mask)``
    where ``mask`` is 1.0 at *pruned* (ineffectual) positions — the binary
    mask convention of the AccelTran sparsity modules (paper Sec. III-B6:
    "if the entry in the mask is 1 ... the corresponding entry is
    ineffectual").
    """
    tau = jnp.asarray(tau, dtype=x.dtype)
    keep = jnp.abs(x) >= tau
    pruned = jnp.where(keep, x, jnp.zeros_like(x))
    mask = (~keep).astype(x.dtype)
    return pruned, mask


def sparsity(x: jax.Array) -> jax.Array:
    """Pruning ratio rho(M) = (# zero elements) / (total elements)."""
    return jnp.mean((x == 0).astype(jnp.float32))


def matmul(x: jax.Array, y: jax.Array) -> jax.Array:
    """Plain GEMM oracle for the tiled Pallas matmul."""
    return jnp.matmul(x, y)


def gelu(x: jax.Array) -> jax.Array:
    """Exact (erf-based) GeLU, matching the MAC-lane GeLU unit."""
    return jax.nn.gelu(x, approximate=False)


def softmax(x: jax.Array) -> jax.Array:
    """Numerically-stable row softmax over the last axis."""
    m = jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(x - m)
    return e / jnp.sum(e, axis=-1, keepdims=True)


def layernorm(x: jax.Array, gamma: jax.Array, beta: jax.Array,
              eps: float = 1e-5) -> jax.Array:
    """Layer norm over the last axis with affine parameters."""
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * gamma + beta


def attention(q: jax.Array, k: jax.Array, v: jax.Array,
              scale: float) -> jax.Array:
    """Single-head scaled dot-product attention (C-OP-4..6 of Table I)."""
    a = jnp.matmul(q, jnp.swapaxes(k, -1, -2)) * scale
    s = softmax(a)
    return jnp.matmul(s, v)


def topk_keep_fraction(x: jax.Array, keep_frac) -> jax.Array:
    """Top-k baseline pruning (SpAtten-style), expressed as a per-row
    quantile threshold so that ``k = keep_frac * row_len`` survivors remain.

    Keeping the top-k |values| of a row is equivalent to thresholding at the
    (1 - k/N) quantile of |row|; the quantile form admits a *traced* k, so a
    single AOT artifact serves every sweep point of Fig. 11(b).
    """
    keep_frac = jnp.asarray(keep_frac, dtype=x.dtype)
    q = jnp.clip(1.0 - keep_frac, 0.0, 1.0)
    thr = jnp.quantile(jnp.abs(x), q, axis=-1, keepdims=True)
    return jnp.where(jnp.abs(x) >= thr, x, jnp.zeros_like(x))
