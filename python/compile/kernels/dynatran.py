"""L1 Pallas kernel: the DynaTran dynamic-pruning module (paper Sec. III-B5).

The hardware module compares every element of an input tile against a
pre-computed threshold tau in a single clock cycle (b*x*y parallel
comparators, Fig. 7) and emits a binary mask alongside the pruned tile.
Here the same operation is expressed as a Pallas kernel so it lowers into
the model's HLO and — on a real TPU — would run as one fused VPU pass over
the VMEM-resident tile (a pure elementwise select: no MXU involvement,
matching the paper's "negligible compute overhead" claim).

interpret=True everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret mode lowers to plain HLO that both jax-CPU and the
Rust xla-crate client can run.  Correctness vs. ``ref.dynatran_prune`` is
asserted by ``python/tests/test_dynatran_kernel.py``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_ROWS = 16


def _dynatran_kernel(tau_ref, x_ref, out_ref, mask_ref):
    """One grid step: prune one (block_rows, N) tile against scalar tau.

    The mask convention follows the AccelTran sparsity pipeline: mask == 1
    marks an *ineffectual* (pruned) element (Sec. III-B6).
    """
    x = x_ref[...]
    tau = tau_ref[0, 0]
    keep = jnp.abs(x) >= tau
    out_ref[...] = jnp.where(keep, x, jnp.zeros_like(x))
    mask_ref[...] = (~keep).astype(x.dtype)


@functools.partial(jax.jit, static_argnames=("block_rows",))
def dynatran_prune(x: jax.Array, tau: jax.Array,
                   block_rows: int = DEFAULT_BLOCK_ROWS):
    """Prune ``x`` (2-D, rows divisible by ``block_rows``) at threshold tau.

    Returns ``(pruned, mask)`` exactly like ``ref.dynatran_prune``.  The
    grid walks row-blocks; each step sees a full-width (block_rows, N) tile,
    mirroring how a PE's DynaTran module consumes one tile per cycle.
    """
    m, n = x.shape
    if m % block_rows != 0:
        raise ValueError(f"rows {m} not divisible by block_rows {block_rows}")
    tau2 = jnp.asarray(tau, dtype=x.dtype).reshape(1, 1)
    grid = (m // block_rows,)
    return pl.pallas_call(
        _dynatran_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda i: (0, 0)),           # tau scalar
            pl.BlockSpec((block_rows, n), lambda i: (i, 0)),  # x row-block
        ],
        out_specs=[
            pl.BlockSpec((block_rows, n), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, n), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, n), x.dtype),
            jax.ShapeDtypeStruct((m, n), x.dtype),
        ],
        interpret=True,
    )(tau2, x)


def prune_only(x: jax.Array, tau: jax.Array,
               block_rows: int = DEFAULT_BLOCK_ROWS) -> jax.Array:
    """Convenience wrapper returning just the pruned values (the L2 model
    threads this through every activation; masks are a hardware-side
    concept consumed by the Rust sparsity modules)."""
    pruned, _ = dynatran_prune(x, tau, block_rows=block_rows)
    return pruned
