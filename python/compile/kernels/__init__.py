"""L1: Pallas kernels for AccelTran's compute hot-spots.

Every kernel here has a pure-jnp oracle of the same name in ``ref.py`` and
a pytest/hypothesis harness under ``python/tests/``.  All kernels run with
``interpret=True`` (CPU PJRT cannot execute Mosaic custom-calls); on a real
TPU the same BlockSpecs express the HBM<->VMEM schedule that the paper's
buffers/MAC-lanes express in ASIC terms (see DESIGN.md §Hardware-Adaptation).
"""

from . import dynatran, layernorm, matmul, ref, softmax  # noqa: F401
