"""L1 Pallas kernel: the softmax module (paper C-OP-5, Sec. III-B3/Fig. 18).

AccelTran dedicates specialized hardware to softmax because it sits on the
attention critical path and, per Fig. 18(b), draws ~half the compute power.
The hardware computes the exponential sum over an entire tile in parallel;
the Pallas analogue is a row-block kernel where each grid step reduces full
rows held in VMEM (max-subtraction for fixed-point-style stability, exp,
row-sum, divide) in one VPU pass.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_ROWS = 16


def _softmax_kernel(x_ref, o_ref):
    x = x_ref[...]
    m = jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(x - m)
    o_ref[...] = e / jnp.sum(e, axis=-1, keepdims=True)


@functools.partial(jax.jit, static_argnames=("block_rows",))
def softmax(x: jax.Array, block_rows: int = DEFAULT_BLOCK_ROWS) -> jax.Array:
    """Row softmax over the last axis of a 2-D array, row-block tiled."""
    m, n = x.shape
    if m % block_rows != 0:
        raise ValueError(f"rows {m} not divisible by block_rows {block_rows}")
    return pl.pallas_call(
        _softmax_kernel,
        grid=(m // block_rows,),
        in_specs=[pl.BlockSpec((block_rows, n), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_rows, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=True,
    )(x)
