"""L1 Pallas kernel: the layer-norm module (paper C-OP-8/11, Sec. III-B3).

Like softmax, layer-norm gets a dedicated hardware module in AccelTran
(10.3% of Edge area, Fig. 18a).  Each grid step normalizes a row-block over
the hidden axis in VMEM: mean, variance, rsqrt, affine — one fused VPU pass
per tile, no MXU traffic.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_ROWS = 16
EPS = 1e-5


def _layernorm_kernel(x_ref, g_ref, b_ref, o_ref):
    x = x_ref[...]
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    o_ref[...] = (x - mu) * jax.lax.rsqrt(var + EPS) * g_ref[...] + b_ref[...]


@functools.partial(jax.jit, static_argnames=("block_rows",))
def layernorm(x: jax.Array, gamma: jax.Array, beta: jax.Array,
              block_rows: int = DEFAULT_BLOCK_ROWS) -> jax.Array:
    """Layer norm over the last axis of 2-D ``x`` with affine params."""
    m, n = x.shape
    if m % block_rows != 0:
        raise ValueError(f"rows {m} not divisible by block_rows {block_rows}")
    g2 = gamma.reshape(1, n)
    b2 = beta.reshape(1, n)
    return pl.pallas_call(
        _layernorm_kernel,
        grid=(m // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, n), lambda i: (i, 0)),
            pl.BlockSpec((1, n), lambda i: (0, 0)),
            pl.BlockSpec((1, n), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=True,
    )(x, g2, b2)
