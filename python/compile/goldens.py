"""Golden-file generator for the Rust integration tests.

Runs the eager (python/XLA) model on fixed seeds and dumps raw
little-endian binaries under ``artifacts/goldens/``.  The Rust runtime
tests load the same AOT HLO artifacts through the PJRT client, execute
them on the same inputs, and assert the outputs match these goldens —
closing the loop python-eager == HLO-text == rust-PJRT.

Usage: cd python && python -m compile.goldens --out-dir ../artifacts/goldens
"""

from __future__ import annotations

import argparse
import json
import os

import jax.numpy as jnp
import numpy as np

from . import model as M
from .kernels import dynatran


def _dump(path: str, arr) -> None:
    np.asarray(arr).astype("<f4" if np.asarray(arr).dtype.kind == "f"
               else "<i4").tofile(path)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts/goldens")
    ap.add_argument("--vocab", type=int, default=1024)
    ap.add_argument("--seq", type=int, default=64)
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    cfg = M.ModelConfig.bert_tiny(vocab=args.vocab, seq=args.seq)

    params = M.init_params(cfg, seed=42)
    rng = np.random.default_rng(42)
    ids8 = rng.integers(0, cfg.vocab, (8, cfg.seq)).astype("<i4")
    labels8 = rng.integers(0, cfg.classes, (8,)).astype("<i4")

    index = {"model": cfg.name, "param_count": M.param_count(cfg),
             "entries": {}}

    def put(name, arr, dtype):
        path = os.path.join(args.out_dir, name + ".bin")
        _dump(path, arr)
        a = np.asarray(arr)
        index["entries"][name] = {"file": name + ".bin",
                                  "shape": list(a.shape), "dtype": dtype}
        print(f"  golden {name}: shape={list(a.shape)}")

    put("params", params, "f32")
    put("ids_b8", ids8, "i32")
    put("labels_b8", labels8, "i32")

    for tau in (0.0, 0.05):
        logits = M.classify(cfg, params, jnp.array(ids8), jnp.float32(tau),
                            jnp.float32(1.0))
        put(f"logits_b8_tau{tau:g}".replace(".", "p"), logits, "f32")

    rho = M.activation_sparsity(cfg, params, jnp.array(ids8),
                                jnp.float32(0.05))
    put("act_sparsity_tau0p05", jnp.reshape(rho, (1,)), "f32")

    # DynaTran kernel golden (matches dynatran_prune_256x256 artifact).
    x = rng.standard_normal((256, 256)).astype("f4")
    pruned, mask = dynatran.dynatran_prune(jnp.array(x), jnp.float32(0.5))
    put("prune_x", x, "f32")
    put("prune_out_tau0p5", pruned, "f32")
    put("prune_mask_tau0p5", mask, "f32")

    # One train step from the golden init (loss + a param checksum).
    m = jnp.zeros_like(params)
    v = jnp.zeros_like(params)
    p2, m2, v2, loss = M.train_step(cfg, params, m, v, jnp.float32(0.0),
                                    jnp.array(ids8[:32].repeat(4, axis=0)[:32]),
                                    jnp.array(labels8.repeat(4)[:32]),
                                    jnp.float32(1e-3))
    put("train_loss0", jnp.reshape(loss, (1,)), "f32")
    put("train_params1_sum", jnp.reshape(jnp.sum(p2), (1,)), "f32")

    with open(os.path.join(args.out_dir, "goldens.json"), "w") as f:
        json.dump(index, f, indent=2)
    print(f"  wrote {os.path.join(args.out_dir, 'goldens.json')}")


if __name__ == "__main__":
    main()
