"""L1 correctness: DynaTran Pallas kernel vs. pure-jnp oracle.

Hypothesis sweeps shapes and thresholds; the kernel must be bit-exact to
the oracle (pure select, no arithmetic reassociation)."""

import hypothesis
import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import dynatran, ref

hypothesis.settings.register_profile(
    "ci", deadline=None, max_examples=25,
    suppress_health_check=[hypothesis.HealthCheck.too_slow])
hypothesis.settings.load_profile("ci")


def _rand(shape, seed):
    return np.random.default_rng(seed).standard_normal(shape).astype("f4")


@hypothesis.given(
    rows=st.integers(1, 8),
    cols=st.integers(1, 96),
    block=st.sampled_from([1, 2, 4, 8, 16]),
    tau=st.floats(0.0, 2.0),
    seed=st.integers(0, 2**16),
)
def test_matches_oracle(rows, cols, block, tau, seed):
    m = rows * block
    x = jnp.array(_rand((m, cols), seed))
    got_p, got_m = dynatran.dynatran_prune(x, tau, block_rows=block)
    exp_p, exp_m = ref.dynatran_prune(x, tau)
    np.testing.assert_array_equal(np.asarray(got_p), np.asarray(exp_p))
    np.testing.assert_array_equal(np.asarray(got_m), np.asarray(exp_m))


def test_tau_zero_is_identity():
    x = jnp.array(_rand((32, 32), 0))
    p, m = dynatran.dynatran_prune(x, 0.0)
    np.testing.assert_array_equal(np.asarray(p), np.asarray(x))
    # nothing pruned (mask all zero) — standard normals are never exactly 0
    assert float(jnp.sum(m)) == 0.0


def test_tau_huge_prunes_everything():
    x = jnp.array(_rand((32, 32), 1))
    p, m = dynatran.dynatran_prune(x, 1e9)
    assert float(jnp.sum(jnp.abs(p))) == 0.0
    assert float(jnp.sum(m)) == 32 * 32


@hypothesis.given(tau1=st.floats(0.0, 1.0), tau2=st.floats(0.0, 1.0),
                  seed=st.integers(0, 2**16))
def test_sparsity_monotone_in_tau(tau1, tau2, seed):
    """rho(tau) is non-decreasing — the invariant the threshold
    calculator's look-up table relies on (paper Sec. III-A)."""
    lo, hi = min(tau1, tau2), max(tau1, tau2)
    x = jnp.array(_rand((32, 32), seed))
    p_lo, _ = dynatran.dynatran_prune(x, lo)
    p_hi, _ = dynatran.dynatran_prune(x, hi)
    assert float(ref.sparsity(p_hi)) >= float(ref.sparsity(p_lo))


def test_mask_marks_exactly_the_zeroed_entries():
    x = jnp.array(_rand((16, 64), 3))
    p, m = dynatran.dynatran_prune(x, 0.7)
    pruned_at = np.asarray(p) == 0.0
    mask_at = np.asarray(m) == 1.0
    np.testing.assert_array_equal(pruned_at, mask_at)


def test_rejects_bad_block():
    with pytest.raises(ValueError):
        dynatran.dynatran_prune(jnp.zeros((10, 4)), 0.1, block_rows=16)
