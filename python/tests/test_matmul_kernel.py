"""L1 correctness: tiled Pallas matmul vs. jnp GEMM oracle.

Covers both the canonical (i, j, k)-grid accumulation kernel and the
full-K-strip variant used inside AOT model artifacts, plus the VMEM/MXU
static analyses used by §Perf."""

import hypothesis
import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import matmul, ref

hypothesis.settings.register_profile(
    "ci", deadline=None, max_examples=20,
    suppress_health_check=[hypothesis.HealthCheck.too_slow])
hypothesis.settings.load_profile("ci")


def _rand(shape, seed):
    return np.random.default_rng(seed).standard_normal(shape).astype("f4")


@hypothesis.given(
    mi=st.integers(1, 4), ni=st.integers(1, 4), ki=st.integers(1, 4),
    bm=st.sampled_from([8, 16]), bn=st.sampled_from([8, 16]),
    bk=st.sampled_from([8, 16]), seed=st.integers(0, 2**16),
)
def test_tiled_matches_oracle(mi, ni, ki, bm, bn, bk, seed):
    m, n, k = mi * bm, ni * bn, ki * bk
    x = jnp.array(_rand((m, k), seed))
    y = jnp.array(_rand((k, n), seed + 1))
    got = matmul.matmul_tiled(x, y, bm=bm, bn=bn, bk=bk)
    exp = ref.matmul(x, y)
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp),
                               rtol=1e-4, atol=1e-5)


@hypothesis.given(
    mi=st.integers(1, 4), ni=st.integers(1, 4),
    k=st.sampled_from([16, 48, 128]), seed=st.integers(0, 2**16),
)
def test_fullk_matches_oracle(mi, ni, k, seed):
    m, n = mi * 16, ni * 16
    x = jnp.array(_rand((m, k), seed))
    y = jnp.array(_rand((k, n), seed + 1))
    got = matmul.matmul_fullk(x, y, bm=16, bn=16)
    np.testing.assert_allclose(np.asarray(got), np.asarray(x) @ np.asarray(y),
                               rtol=1e-4, atol=1e-5)


def test_paper_tile_shape():
    """The paper's 16x16 tile at BERT-Tiny h=128 — the exact shape the
    Rust MAC-lane model charges n_o/M cycles for."""
    x = jnp.array(_rand((64, 128), 0))
    y = jnp.array(_rand((128, 128), 1))
    got = matmul.matmul_tiled(x, y, bm=16, bn=16, bk=16)
    np.testing.assert_allclose(np.asarray(got), np.asarray(x) @ np.asarray(y),
                               rtol=1e-4, atol=1e-5)


def test_shape_validation():
    x = jnp.zeros((32, 32))
    with pytest.raises(ValueError):
        matmul.matmul_tiled(x, jnp.zeros((16, 32)))   # inner mismatch
    with pytest.raises(ValueError):
        matmul.matmul_tiled(jnp.zeros((30, 32)), jnp.zeros((32, 32)))


def test_vmem_bytes():
    # (16*16 + 16*16 + 16*16) * 4B = 3 KiB per grid step at paper tiles
    assert matmul.vmem_bytes(16, 16, 16) == 3 * 16 * 16 * 4


def test_mxu_utilization_bounds():
    assert matmul.mxu_utilization(128, 128, 128) == 1.0
    assert 0.0 < matmul.mxu_utilization(16, 16, 16) < 0.01
