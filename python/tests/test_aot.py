"""AOT pipeline tests: every artifact lowers to valid HLO text, manifests
agree with the model layout, and a lowered computation compiled through
jax's own CPU client reproduces the eager result (the same HLO text the
Rust PJRT client consumes)."""

import json
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax._src.lib import xla_client as xc

from compile import aot
from compile import model as M

CFG = M.ModelConfig.bert_tiny(vocab=128, seq=16)   # small: fast lowering


@pytest.fixture(scope="module")
def exported():
    d = tempfile.mkdtemp(prefix="acceltran_aot_")
    manifest = aot.export_all(CFG, d, only=["classify_b1",
                                            "dynatran_prune_256x256"],
                              verbose=False)
    return d, manifest


def test_manifest_schema(exported):
    d, manifest = exported
    assert manifest["model"]["param_count"] == M.param_count(CFG)
    assert len(manifest["params"]) == len(M.param_specs(CFG))
    for art in manifest["artifacts"].values():
        assert os.path.exists(os.path.join(d, art["file"]))
        assert art["hlo_bytes"] > 0
        for a in art["args"]:
            assert a["dtype"] in ("float32", "int32")


def test_manifest_json_roundtrip(exported):
    d, manifest = exported
    with open(os.path.join(d, "manifest.json")) as f:
        loaded = json.load(f)
    assert loaded == manifest


def test_hlo_text_is_parseable_module(exported):
    d, _ = exported
    text = open(os.path.join(d, "classify_b1.hlo.txt")).read()
    assert text.startswith("HloModule")
    assert "ENTRY" in text


def test_hlo_text_has_expected_signature(exported):
    """The emitted HLO entry computation must expose exactly the argument
    list the manifest promises (the contract the Rust runtime relies on)."""
    d, manifest = exported
    text = open(os.path.join(d, "classify_b1.hlo.txt")).read()
    np_ = M.param_count(CFG)
    assert f"f32[{np_}]" in text             # flat params parameter
    assert f"s32[1,{CFG.seq}]" in text       # token ids parameter
    assert "parameter(0)" in text and "parameter(2)" in text
    assert manifest["artifacts"]["classify_b1"]["args"][0]["shape"] == [np_]


def test_lowered_compiles_and_matches_eager(exported):
    """Compile the same lowered computation jax-side and compare against
    eager — validates the lowering that produced the artifact text.  (The
    text->PJRT execution round-trip itself is covered by the Rust
    integration tests against Python-generated goldens.)"""
    from compile.kernels import dynatran

    def fn(x, tau):
        return tuple(dynatran.dynatran_prune(x, tau))

    lowered = jax.jit(fn).lower(
        jax.ShapeDtypeStruct((256, 256), jnp.float32),
        jax.ShapeDtypeStruct((), jnp.float32))
    text = aot.to_hlo_text(lowered)
    assert text.startswith("HloModule")
    compiled = lowered.compile()
    rng = np.random.default_rng(0)
    x = jnp.array(rng.standard_normal((256, 256)).astype("f4"))
    tau = jnp.float32(0.5)
    got_p, got_m = compiled(x, tau)
    exp_p, exp_m = dynatran.dynatran_prune(x, tau)
    np.testing.assert_array_equal(np.asarray(got_p), np.asarray(exp_p))
    np.testing.assert_array_equal(np.asarray(got_m), np.asarray(exp_m))
