"""L2 correctness: model shapes, prune-mode semantics, pallas/jnp parity,
training-step sanity, and the flat-parameter layout contract with Rust."""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.kernels import ref

CFG = M.ModelConfig.bert_tiny()


@pytest.fixture(scope="module")
def params():
    return M.init_params(CFG, seed=0)


@pytest.fixture(scope="module")
def batch():
    rng = np.random.default_rng(7)
    ids = jnp.array(rng.integers(0, CFG.vocab, (4, CFG.seq)), jnp.int32)
    labels = jnp.array(rng.integers(0, CFG.classes, (4,)), jnp.int32)
    return ids, labels


def test_param_count_matches_specs(params):
    assert params.shape == (M.param_count(CFG),)
    total = sum(math.prod(s) for _, s, _ in M.param_specs(CFG))
    assert total == M.param_count(CFG)


def test_param_specs_are_unique_and_ordered():
    names = [n for n, _, _ in M.param_specs(CFG)]
    assert len(names) == len(set(names))
    assert names[0] == "embed.word" and names[-1] == "cls.b"


def test_unpack_roundtrip(params):
    up = M.unpack_params(CFG, params)
    flat_again = jnp.concatenate([up[n].reshape(-1)
                                  for n, _, _ in M.param_specs(CFG)])
    np.testing.assert_array_equal(np.asarray(flat_again), np.asarray(params))


def test_layernorm_gains_init_to_one(params):
    up = M.unpack_params(CFG, params)
    np.testing.assert_array_equal(np.asarray(up["layer0.ln1.gamma"]),
                                  np.ones(CFG.hidden, "f4"))


def test_classify_shape(params, batch):
    ids, _ = batch
    logits = M.classify(CFG, params, ids, jnp.float32(0.0), jnp.float32(1.0))
    assert logits.shape == (4, CFG.classes)
    assert np.isfinite(np.asarray(logits)).all()


def test_tau_zero_equals_no_pruning(params, batch):
    ids, _ = batch
    a = M.classify(CFG, params, ids, jnp.float32(0.0), jnp.float32(1.0),
                   prune_mode=M.PRUNE_DYNATRAN)
    b = M.classify(CFG, params, ids, jnp.float32(0.0), jnp.float32(1.0),
                   prune_mode=M.PRUNE_NONE)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-5, atol=1e-6)


def test_keepfrac_one_is_near_identity(params, batch):
    ids, _ = batch
    a = M.classify(CFG, params, ids, jnp.float32(0.0), jnp.float32(1.0),
                   prune_mode=M.PRUNE_TOPK)
    b = M.classify(CFG, params, ids, jnp.float32(0.0), jnp.float32(1.0),
                   prune_mode=M.PRUNE_NONE)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-3, atol=1e-4)


def test_pruning_changes_logits(params, batch):
    ids, _ = batch
    a = M.classify(CFG, params, ids, jnp.float32(0.0), jnp.float32(1.0))
    b = M.classify(CFG, params, ids, jnp.float32(0.2), jnp.float32(1.0))
    assert not np.allclose(np.asarray(a), np.asarray(b))


def test_pallas_path_matches_jnp_path(params, batch):
    ids, _ = batch
    for tau in (0.0, 0.05):
        a = M.classify(CFG, params, ids, jnp.float32(tau), jnp.float32(1.0),
                       use_pallas=False)
        b = M.classify(CFG, params, ids, jnp.float32(tau), jnp.float32(1.0),
                       use_pallas=True)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-3, atol=1e-4)


def test_activation_sparsity_monotone(params, batch):
    ids, _ = batch
    rhos = [float(M.activation_sparsity(CFG, params, ids, jnp.float32(t)))
            for t in (0.0, 0.02, 0.05, 0.1)]
    assert all(b >= a - 1e-6 for a, b in zip(rhos, rhos[1:]))
    assert rhos[-1] > 0.3   # tau=0.1 prunes a large fraction post-LN


def test_train_step_reduces_loss(params, batch):
    ids, labels = batch
    fp = params
    m = jnp.zeros_like(fp)
    v = jnp.zeros_like(fp)
    losses = []
    for step in range(12):
        fp, m, v, loss = M.train_step(CFG, fp, m, v, jnp.float32(step),
                                      ids, labels, jnp.float32(3e-3))
        losses.append(float(loss))
    # overfit 4 examples: loss must drop substantially
    assert losses[-1] < losses[0] * 0.6, losses


def test_accuracy_metric():
    logits = jnp.array([[2.0, -1.0], [0.0, 3.0], [1.0, 0.5]])
    labels = jnp.array([0, 1, 1])
    assert float(M.accuracy(logits, labels)) == pytest.approx(2.0 / 3.0)


def test_topk_keep_fraction_keeps_expected_count():
    x = jnp.array(np.random.default_rng(0).standard_normal((8, 64)), jnp.float32)
    kept = ref.topk_keep_fraction(x, jnp.float32(0.25))
    nz = np.count_nonzero(np.asarray(kept), axis=-1)
    assert (np.abs(nz - 16) <= 1).all()
