"""L1 correctness: softmax + layer-norm Pallas modules vs. oracles."""

import hypothesis
import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import layernorm, ref, softmax

hypothesis.settings.register_profile(
    "ci", deadline=None, max_examples=20,
    suppress_health_check=[hypothesis.HealthCheck.too_slow])
hypothesis.settings.load_profile("ci")


def _rand(shape, seed, scale=1.0):
    return (scale *
            np.random.default_rng(seed).standard_normal(shape)).astype("f4")


@hypothesis.given(rows=st.integers(1, 6), cols=st.integers(1, 80),
                  block=st.sampled_from([1, 4, 16]),
                  scale=st.sampled_from([0.1, 1.0, 20.0]),
                  seed=st.integers(0, 2**16))
def test_softmax_matches_oracle(rows, cols, block, scale, seed):
    m = rows * block
    x = jnp.array(_rand((m, cols), seed, scale))
    got = softmax.softmax(x, block_rows=block)
    exp = ref.softmax(x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp),
                               rtol=1e-5, atol=1e-6)


def test_softmax_rows_sum_to_one():
    x = jnp.array(_rand((32, 64), 0, 30.0))   # large logits: stability check
    got = np.asarray(softmax.softmax(x))
    np.testing.assert_allclose(got.sum(axis=-1), np.ones(32), rtol=1e-5)
    assert np.isfinite(got).all()


@hypothesis.given(rows=st.integers(1, 6), cols=st.integers(2, 96),
                  block=st.sampled_from([1, 4, 16]),
                  seed=st.integers(0, 2**16))
def test_layernorm_matches_oracle(rows, cols, block, seed):
    m = rows * block
    x = jnp.array(_rand((m, cols), seed))
    g = jnp.array(_rand((cols,), seed + 1))
    b = jnp.array(_rand((cols,), seed + 2))
    got = layernorm.layernorm(x, g, b, block_rows=block)
    exp = ref.layernorm(x, g, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp),
                               rtol=1e-4, atol=1e-5)


def test_layernorm_output_is_normalized():
    x = jnp.array(_rand((16, 128), 5, 7.0))
    ones = jnp.ones((128,), jnp.float32)
    zeros = jnp.zeros((128,), jnp.float32)
    got = np.asarray(layernorm.layernorm(x, ones, zeros))
    np.testing.assert_allclose(got.mean(axis=-1), np.zeros(16), atol=1e-5)
    np.testing.assert_allclose(got.std(axis=-1), np.ones(16), atol=1e-2)


def test_block_validation():
    with pytest.raises(ValueError):
        softmax.softmax(jnp.zeros((10, 8)), block_rows=16)
    with pytest.raises(ValueError):
        layernorm.layernorm(jnp.zeros((10, 8)), jnp.ones(8), jnp.zeros(8),
                            block_rows=16)
